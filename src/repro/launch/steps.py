"""Step-function + sharding assembly shared by dryrun/train/serve.

Builds, for an (arch, shape, mesh) cell:
  * the step function (train_step / prefill_step / decode_step)
  * abstract input/state ShapeDtypeStructs
  * NamedShardings resolved through the logical-axis rule engine
    (with FSDP weight sharding for the multi-billion-parameter archs,
    and KV-sequence sharding for the 500k-context decode cells).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import InputShape, ModelConfig, OptimizerConfig
from repro.models import build_model, input_axes, input_specs
from repro.optimizer import adamw
from repro.sharding.rules import DEFAULT_RULES, RuleSet

# archs whose weights + optimizer state need ZeRO/FSDP sharding over `data`
FSDP_PARAM_THRESHOLD = 3e9


def needs_fsdp(cfg: ModelConfig) -> bool:
    return cfg.param_count() > FSDP_PARAM_THRESHOLD


def rules_for(cfg: ModelConfig, shape: InputShape, mesh,
              overrides: Optional[Dict] = None) -> RuleSet:
    rules = dict(DEFAULT_RULES)
    if needs_fsdp(cfg):
        rules["embed"] = "data"       # FSDP: weight embed dims over data
        rules["fsdp_embed"] = "data"
        rules["expert_mlp"] = None
    if shape.kind == "decode":
        kv_axes = []
        if shape.global_batch < mesh.shape.get("data", 1):
            # long-context decode: batch can't fill the data axis — shard
            # the KV cache sequence dim instead (flash-decoding layout)
            kv_axes.append("data")
        if cfg.num_kv_heads % mesh.shape.get("model", 1) != 0:
            # KV heads can't split the model axis — spread the cache over
            # sequence instead of replicating gigabytes per device
            kv_axes.append("model")
        if kv_axes:
            rules["kv_seq"] = tuple(kv_axes)
    if overrides:
        rules.update(overrides)
    return RuleSet(mesh, rules)


def _axes_is_leaf(x):
    return isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x)


def shardings_for_tree(ruleset: RuleSet, axes_tree, sds_tree):
    def one(axes, sds):
        return ruleset.sharding(axes, sds.shape)
    return jax.tree.map(one, axes_tree, sds_tree, is_leaf=_axes_is_leaf)


def replicated(mesh):
    from jax.sharding import NamedSharding, PartitionSpec as P
    return NamedSharding(mesh, P())


@dataclasses.dataclass
class CellPlan:
    """Everything needed to lower one (arch x shape x mesh) cell."""
    step_fn: Callable
    arg_sds: Tuple
    arg_shardings: Tuple
    out_shardings: Any
    ruleset: RuleSet
    description: str


def build_model_for_scale(cfg: ModelConfig, causal_skip: bool = False,
                          ruleset: Optional[RuleSet] = None,
                          moe_dispatch: str = "onehot"):
    """Model with large-scale execution strategies selected: flash
    (recompute-in-backward) attention, factored WKV6, and explicit
    per-layer activation sharding constraints."""
    kw = {} if cfg.is_encdec else {"moe_dispatch": moe_dispatch}
    model = build_model(cfg, attn_impl="flash", rwkv_mode="factored",
                        causal_skip=causal_skip, **kw)
    if ruleset is not None:
        def constrain(x):
            sh = ruleset.sharding(("batch", "seq", None), x.shape)
            return jax.lax.with_sharding_constraint(x, sh)
        model.act_constraint = constrain

        from repro.models import common as model_common

        def generic_constrain(x, logical_axes):
            sh = ruleset.sharding(logical_axes, x.shape)
            return jax.lax.with_sharding_constraint(x, sh)
        model_common.set_constrainer(generic_constrain)
    return model


# target tokens per device per microbatch: bounds live activation memory
MICROBATCH_TOKENS_PER_DEVICE = 16384


def default_microbatches(shape: InputShape, mesh,
                         cfg: Optional[ModelConfig] = None) -> int:
    data_ways = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    local_tokens = shape.global_batch * shape.seq_len // max(data_ways, 1)
    k = max(1, local_tokens // MICROBATCH_TOKENS_PER_DEVICE)
    if cfg is not None and cfg.param_count() > 5e10:
        # 100B-class: halve live activations again (dbrx fits 16 GB at 8)
        k *= 2
    while k > 1 and shape.global_batch % k:
        k -= 1
    return k


def make_train_plan(cfg: ModelConfig, shape: InputShape, mesh,
                    opt_cfg: Optional[OptimizerConfig] = None,
                    rule_overrides: Optional[Dict] = None,
                    causal_skip: bool = False,
                    microbatches: Optional[int] = None,
                    moe_dispatch: str = "onehot") -> CellPlan:
    opt_cfg = opt_cfg or OptimizerConfig()
    rs = rules_for(cfg, shape, mesh, rule_overrides)
    model = build_model_for_scale(cfg, causal_skip=causal_skip, ruleset=rs,
                                  moe_dispatch=moe_dispatch)
    if microbatches is None:
        microbatches = default_microbatches(shape, mesh, cfg)

    params_sds = jax.eval_shape(model.init, jax.random.key(0))
    axes = model.param_axes()
    param_sh = shardings_for_tree(rs, axes, params_sds)
    mu_sh = param_sh
    nu_sh = param_sh
    state_sh = (param_sh, adamw.AdamWState(step=replicated(mesh),
                                           mu=mu_sh, nu=nu_sh))
    opt_sds = adamw.AdamWState(
        step=jax.ShapeDtypeStruct((), jnp.int32),
        mu=jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32),
                        params_sds),
        nu=jax.tree.map(lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32),
                        params_sds))
    state_sds = (params_sds, opt_sds)

    batch_sds = input_specs(cfg, shape)
    batch_axes = input_axes(cfg, shape)
    batch_sh = shardings_for_tree(rs, batch_axes, batch_sds)

    nmicro = microbatches

    def train_step(state, batch):
        params, opt_state = state
        if nmicro <= 1:
            loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
        else:
            # gradient accumulation: scan over microbatches; grads f32
            # accumulate in the (sharded) param layout
            def split(x):
                return x.reshape((nmicro, x.shape[0] // nmicro)
                                 + x.shape[1:])

            micro = jax.tree.map(split, batch)

            def acc_body(carry, mb):
                loss_acc, gacc = carry
                loss, grads = jax.value_and_grad(model.loss_fn)(params, mb)
                gacc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32) / nmicro,
                    gacc, grads)
                return (loss_acc + loss / nmicro, gacc), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                acc_body, (jnp.zeros((), jnp.float32), g0), micro)
        params, opt_state, metrics = adamw.apply_updates(
            opt_cfg, params, grads, opt_state)
        metrics["loss"] = loss
        return (params, opt_state), metrics

    metrics_sh = {"loss": replicated(mesh), "grad_norm": replicated(mesh),
                  "lr": replicated(mesh)}
    return CellPlan(step_fn=train_step,
                    arg_sds=(state_sds, batch_sds),
                    arg_shardings=(state_sh, batch_sh),
                    out_shardings=(state_sh, metrics_sh),
                    ruleset=rs,
                    description=(f"train {cfg.name} {shape.name} "
                                 f"(microbatches={microbatches})"))


def make_prefill_plan(cfg: ModelConfig, shape: InputShape, mesh,
                      rule_overrides: Optional[Dict] = None,
                      causal_skip: bool = False,
                      moe_dispatch: str = "onehot",
                      last_logit: bool = False) -> CellPlan:
    rs = rules_for(cfg, shape, mesh, rule_overrides)
    model = build_model_for_scale(cfg, causal_skip=causal_skip, ruleset=rs,
                                  moe_dispatch=moe_dispatch)
    if last_logit and not cfg.is_encdec:
        model.prefill_last_only = True
    params_sds = jax.eval_shape(model.init, jax.random.key(0))
    param_sh = shardings_for_tree(rs, model.param_axes(), params_sds)
    batch_sds = input_specs(cfg, shape)
    batch_sh = shardings_for_tree(rs, input_axes(cfg, shape), batch_sds)

    if cfg.is_encdec:
        def prefill_step(params, batch):
            logits, cache = model.prefill(params, batch["frames"],
                                          batch["tokens"])
            return logits[:, -1], cache
    else:
        key = "embeds" if model.takes_embeds else "tokens"

        def prefill_step(params, batch):
            logits, cache = model.prefill(params, batch[key])
            return logits[:, -1], cache

    # output cache shardings via cache axes
    cache_sds, cache_axes = model.cache_spec(shape.global_batch,
                                             shape.seq_len)
    cache_sh = shardings_for_tree(rs, cache_axes, cache_sds)
    logits_sh = rs.sharding(("batch", "act_vocab"),
                            (shape.global_batch, cfg.padded_vocab_size))
    return CellPlan(step_fn=prefill_step,
                    arg_sds=(params_sds, batch_sds),
                    arg_shardings=(param_sh, batch_sh),
                    out_shardings=(logits_sh, cache_sh),
                    ruleset=rs,
                    description=f"prefill {cfg.name} {shape.name}")


def make_decode_plan(cfg: ModelConfig, shape: InputShape, mesh,
                     rule_overrides: Optional[Dict] = None,
                     moe_dispatch: str = "onehot") -> CellPlan:
    rs = rules_for(cfg, shape, mesh, rule_overrides)
    model = build_model_for_scale(cfg, ruleset=rs,
                                  moe_dispatch=moe_dispatch)
    params_sds = jax.eval_shape(model.init, jax.random.key(0))
    param_sh = shardings_for_tree(rs, model.param_axes(), params_sds)

    batch_sds = input_specs(cfg, shape)          # tokens, pos, cache
    batch_axes = input_axes(cfg, shape)
    batch_sh = shardings_for_tree(rs, batch_axes, batch_sds)

    def decode_step(params, batch):
        logits, cache = model.decode_step(params, batch["tokens"],
                                          batch["pos"], batch["cache"])
        return logits[:, -1], cache

    logits_sh = rs.sharding(("batch", "act_vocab"),
                            (shape.global_batch, cfg.padded_vocab_size))
    cache_sh = batch_sh["cache"]
    return CellPlan(step_fn=decode_step,
                    arg_sds=(params_sds, batch_sds),
                    arg_shardings=(param_sh, batch_sh),
                    out_shardings=(logits_sh, cache_sh),
                    ruleset=rs,
                    description=f"decode {cfg.name} {shape.name}")


def make_plan(cfg: ModelConfig, shape: InputShape, mesh,
              rule_overrides: Optional[Dict] = None,
              causal_skip: bool = False,
              moe_dispatch: str = "onehot",
              last_logit: bool = False) -> CellPlan:
    if shape.kind == "train":
        return make_train_plan(cfg, shape, mesh,
                               rule_overrides=rule_overrides,
                               causal_skip=causal_skip,
                               moe_dispatch=moe_dispatch)
    if shape.kind == "prefill":
        return make_prefill_plan(cfg, shape, mesh,
                                 rule_overrides=rule_overrides,
                                 causal_skip=causal_skip,
                                 moe_dispatch=moe_dispatch,
                                 last_logit=last_logit)
    return make_decode_plan(cfg, shape, mesh,
                            rule_overrides=rule_overrides,
                            moe_dispatch=moe_dispatch)
