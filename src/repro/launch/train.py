"""Production training CLI.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-360m \
        --steps 100 [--smoke] [--compress-grads] [--resume]

On a real pod this runs under the production mesh; in this container use
--smoke (reduced config, 1-device mesh) to exercise the identical driver.
"""
from __future__ import annotations

import argparse

from repro.config import (SHAPES_BY_NAME, InputShape, OptimizerConfig,
                          TrainConfig, get_arch, get_smoke_arch)
from repro.launch.mesh import make_production_mesh, make_test_mesh
from repro.runtime.train_loop import Trainer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-360m")
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + test mesh (CPU container)")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    if args.smoke:
        cfg = get_smoke_arch(args.arch)
        shape = InputShape("smoke", seq_len=args.seq,
                           global_batch=args.batch, kind="train")
        mesh = make_test_mesh(1, 1)
    else:
        cfg = get_arch(args.arch)
        shape = SHAPES_BY_NAME[args.shape]
        mesh = make_production_mesh()

    tc = TrainConfig(
        shape=shape,
        optimizer=OptimizerConfig(lr=args.lr, warmup_steps=20,
                                  total_steps=args.steps,
                                  compress_grads=args.compress_grads),
        checkpoint_every=args.ckpt_every, checkpoint_dir=args.ckpt_dir)
    trainer = Trainer(cfg, tc, mesh,
                      metrics_path=f"{args.ckpt_dir}/metrics.jsonl")
    report = trainer.run(args.steps, resume=args.resume)
    print(f"final loss {report.final_loss:.4f} after {args.steps} steps "
          f"({report.restarts} restarts, "
          f"{report.straggler_events} straggler events)")


if __name__ == "__main__":
    main()
