"""Ad-hoc calibration (paper §4.2, Algorithm 1).

From the proxy's decision scores over the full collection and a small
oracle-labeled sample, reconstruct the class-conditional score
distributions:

  1. Discretize [0, 1] into `num_bins` bins.
  2. Stratified sampling: sample from each bin proportionally to its
     population, so low-density regions are represented.
  3. Oracle-label the sample; split scores into positive / negative sets.
  4. Jitter: inject low-density mass into empty bins (information
     recovery — empty bins must not read as "certainly zero").
  5. Density estimation via *linear interpolation* of bin masses
     (distortion-free vs KDE, per the paper).
  6. Moving-average smoothing to suppress sampling noise.

Outputs piecewise-linear PDFs/CDFs for both classes plus the estimated
positive prior — everything threshold selection (Algorithm 2) needs.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Tuple

import numpy as np

from repro.config.base import CascadeConfig


@dataclasses.dataclass
class ClassDensity:
    """Piecewise-linear density over score bins."""
    edges: np.ndarray       # (B+1,)
    centers: np.ndarray     # (B,)
    pdf: np.ndarray         # (B,) density at centers (integrates to ~1)
    cdf_edges: np.ndarray   # (B+1,) CDF evaluated at edges

    def cdf(self, x) -> np.ndarray:
        return np.interp(x, self.edges, self.cdf_edges)


@dataclasses.dataclass
class Calibration:
    pdf_pos: ClassDensity
    pdf_neg: ClassDensity
    prior_pos: float          # F^+ (fraction of positives)
    edges: np.ndarray         # discretization (steps of Algorithm 2)
    sample_idx: np.ndarray    # labeled sample indices (oracle calls)
    sample_labels: np.ndarray
    sample_scores: np.ndarray = None


def discretize(num_bins: int) -> np.ndarray:
    return np.linspace(0.0, 1.0, num_bins + 1)


def stratified_sample(scores: np.ndarray, frac: float, edges: np.ndarray,
                      rng: np.random.Generator) -> np.ndarray:
    """Proportional per-bin sampling without replacement. Returns indices."""
    n = len(scores)
    target = max(int(np.ceil(frac * n)), 8)
    bin_ids = np.clip(np.searchsorted(edges, scores, side="right") - 1,
                      0, len(edges) - 2)
    chosen = []
    for b in range(len(edges) - 1):
        members = np.nonzero(bin_ids == b)[0]
        if len(members) == 0:
            continue
        take = int(round(target * len(members) / n))
        take = max(take, 1) if len(members) > 0 else 0
        take = min(take, len(members))
        chosen.append(rng.choice(members, size=take, replace=False))
    idx = np.concatenate(chosen) if chosen else np.array([], np.int64)
    rng.shuffle(idx)
    return idx


def _hist_density(scores: np.ndarray, edges: np.ndarray) -> np.ndarray:
    counts, _ = np.histogram(scores, bins=edges)
    return counts.astype(np.float64)


def _jitter(mass: np.ndarray, density: float,
            rng: np.random.Generator) -> np.ndarray:
    """Inject low random mass into empty bins (Algorithm 1 step 1)."""
    total = mass.sum()
    if total <= 0:
        return mass
    empty = mass == 0
    if not empty.any():
        return mass
    inj = rng.uniform(0.5, 1.5, size=int(empty.sum())) * density * total \
        / max(len(mass), 1)
    out = mass.copy()
    out[empty] = inj
    return out


def _moving_average(x: np.ndarray, window: int) -> np.ndarray:
    if window <= 1:
        return x
    kernel = np.ones(window) / window
    pad = window // 2
    xp = np.pad(x, (pad, pad), mode="edge")
    out = np.convolve(xp, kernel, mode="valid")
    return out[:len(x)]


def _density_from_mass(mass: np.ndarray, edges: np.ndarray) -> ClassDensity:
    centers = 0.5 * (edges[:-1] + edges[1:])
    width = np.diff(edges)
    total = mass.sum()
    pdf = (mass / total) / width if total > 0 else np.zeros_like(mass)
    # CDF at edges by integrating the piecewise-linear pdf over bins
    # (equivalently: cumulative normalized mass)
    cdf = np.concatenate([[0.0], np.cumsum(mass / max(total, 1e-12))])
    cdf = np.clip(cdf, 0.0, 1.0)
    cdf[-1] = 1.0
    return ClassDensity(edges=edges, centers=centers, pdf=pdf,
                        cdf_edges=cdf)


def reconstruct_density(sample_scores: np.ndarray, edges: np.ndarray,
                        cfg: CascadeConfig,
                        rng: np.random.Generator) -> ClassDensity:
    """Jitter -> linear-interp DE -> moving-average smoothing."""
    mass = _hist_density(sample_scores, edges)
    mass = _jitter(mass, cfg.jitter_density, rng)
    mass = _moving_average(mass, cfg.ma_window)
    return _density_from_mass(mass, edges)


def calibrate(scores: np.ndarray, oracle_label_fn: Callable,
              cfg: CascadeConfig,
              rng: Optional[np.random.Generator] = None) -> Calibration:
    """Algorithm 1. ``oracle_label_fn(indices) -> labels`` (counted by the
    caller's oracle object)."""
    rng = rng or np.random.default_rng(cfg.seed)
    edges = discretize(cfg.num_bins)
    idx = stratified_sample(scores, cfg.calib_fraction, edges, rng)
    labels = np.asarray(oracle_label_fn(idx)).astype(bool)
    s = scores[idx]
    pos_scores, neg_scores = s[labels], s[~labels]
    pdf_pos = reconstruct_density(pos_scores, edges, cfg, rng)
    pdf_neg = reconstruct_density(neg_scores, edges, cfg, rng)
    prior = float(labels.mean()) if len(labels) else 0.5
    return Calibration(pdf_pos=pdf_pos, pdf_neg=pdf_neg, prior_pos=prior,
                       edges=edges, sample_idx=idx, sample_labels=labels,
                       sample_scores=s)


# -- alternative density estimators for the paper's Table 4 ablation --------

def naive_density(sample_scores: np.ndarray, edges: np.ndarray
                  ) -> ClassDensity:
    """No jitter, no smoothing (the 'Naive'/'w/o Jitter' baselines)."""
    return _density_from_mass(_hist_density(sample_scores, edges), edges)


def beta_fit_density(sample_scores: np.ndarray, edges: np.ndarray
                     ) -> ClassDensity:
    """Method-of-moments Beta fit (Table 4 'B')."""
    s = np.clip(sample_scores, 1e-4, 1 - 1e-4)
    if len(s) < 2:
        return naive_density(sample_scores, edges)
    m, v = float(s.mean()), float(max(s.var(), 1e-6))
    common = m * (1 - m) / v - 1
    a, b = max(m * common, 0.05), max((1 - m) * common, 0.05)
    centers = 0.5 * (edges[:-1] + edges[1:])
    # unnormalized Beta pdf evaluated at centers
    logpdf = (a - 1) * np.log(centers + 1e-12) \
        + (b - 1) * np.log(1 - centers + 1e-12)
    logpdf -= logpdf.max()
    mass = np.exp(logpdf)
    return _density_from_mass(mass, edges)


def importance_density(sample_scores: np.ndarray, weights: np.ndarray,
                       edges: np.ndarray) -> ClassDensity:
    """Importance-weighted histogram (Table 4 'IS')."""
    counts, _ = np.histogram(sample_scores, bins=edges, weights=weights)
    return _density_from_mass(counts.astype(np.float64), edges)
