"""ScaleDoc's lightweight query-aware proxy encoder (paper §3.2, §5).

A 3-layer MLP ``E : R^D -> R^l`` maps LLM embeddings (documents and the
query) into a shared latent space; the decision score is the cosine
similarity between latents. A projector head (standard contrastive-learning
practice, paper §5) is appended during training and discarded at inference.

Scores are mapped from cosine [-1, 1] to [0, 1] via (1+cos)/2 to match the
paper's stated score interval.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import ProxyConfig
from repro.models.common import dense_init

Params = Dict[str, Any]


def encoder_init(key, cfg: ProxyConfig, dtype=jnp.float32) -> Params:
    dims = [cfg.embed_dim] + [cfg.hidden_dim] * (cfg.num_layers - 1) \
        + [cfg.latent_dim]
    keys = jax.random.split(key, cfg.num_layers + 1)
    layers = []
    for i in range(cfg.num_layers):
        layers.append({
            "w": dense_init(keys[i], dims[i], (dims[i + 1],), dtype),
            "b": jnp.zeros((dims[i + 1],), dtype),
        })
    proj = {
        "w": dense_init(keys[-1], cfg.latent_dim, (cfg.proj_dim,), dtype),
        "b": jnp.zeros((cfg.proj_dim,), dtype),
    }
    return {"layers": {f"l{i}": l for i, l in enumerate(layers)},
            "proj": proj}


def encoder_axes(cfg: ProxyConfig) -> Params:
    layers = {}
    for i in range(cfg.num_layers):
        layers[f"l{i}"] = {"w": ("proxy_in", "proxy_out"),
                           "b": ("proxy_out",)}
    return {"layers": layers,
            "proj": {"w": ("proxy_in", "proxy_out"), "b": ("proxy_out",)}}


def encoder_apply(params: Params, e: jnp.ndarray) -> jnp.ndarray:
    """e: (..., D) -> latent z: (..., l)."""
    x = e
    n = len(params["layers"])
    for i in range(n):
        l = params["layers"][f"l{i}"]
        x = x @ l["w"] + l["b"]
        if i < n - 1:
            x = jax.nn.gelu(x)
    return x


def projector_apply(params: Params, z: jnp.ndarray) -> jnp.ndarray:
    """Training-only projector head."""
    p = params["proj"]
    return z @ p["w"] + p["b"]


def l2_normalize(x: jnp.ndarray, eps: float = 1e-8) -> jnp.ndarray:
    return x / jnp.maximum(jnp.linalg.norm(x, axis=-1, keepdims=True), eps)


def cosine(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum(l2_normalize(a) * l2_normalize(b), axis=-1)


def decision_scores(params: Params, e_q: jnp.ndarray, e_docs: jnp.ndarray
                    ) -> jnp.ndarray:
    """(1 + cos(z_q, z_d)) / 2 in [0, 1]. e_q: (D,); e_docs: (N, D)."""
    z_q = encoder_apply(params, e_q)
    z_d = encoder_apply(params, e_docs)
    cos = l2_normalize(z_d) @ l2_normalize(z_q)
    return (1.0 + cos) / 2.0
