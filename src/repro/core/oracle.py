"""Oracle LLM interfaces.

* SimulatedOracle — planted ground-truth labels + optional flip noise +
  a FLOPs cost model (the container has no GPT-4o / GPU; the paper's own
  Table 2 reports cost in FLOPs, which we mirror). Counts invocations.
* LMOracle — runs one of the assigned-architecture LMs as a judge: scores
  a verbalized (query, document) pair by comparing yes/no token logits.
  Used by the end-to-end LM example; slow on CPU, so sized down there.
"""
from __future__ import annotations

import dataclasses
import threading
from typing import Callable, Optional, Sequence

import numpy as np

# FLOPs cost model per document. Provenance: paper §6.2 (Table 2
# "computational cost" column) reports TOTAL FLOPs over a 10k-document
# collection of ~400-word documents; we normalize each per document.
#   oracle LLM (GPT-4o class)  >500 PFLOPs / 10k docs -> ~50 TFLOPs/doc
#   3B proxy-LLM baseline        27 PFLOPs / 10k docs
#   1B proxy-LLM baseline        10 PFLOPs / 10k docs
#   ScaleDoc MLP proxy           ~2 TFLOPs / 10k docs -> ~0.2 GFLOPs/doc
# (sanity check: ~2*params*tokens forward FLOPs at a few hundred tokens
# per document lands within ~2x of each row). benchmarks/ and
# QueryStats report cost in these units because the container has no
# GPT-4o; the ratios, not the absolute counts, carry the paper's story.
ORACLE_FLOPS_PER_DOC = 500e15 / 10_000
PROXY_LLM_3B_FLOPS_PER_DOC = 27e15 / 10_000
PROXY_LLM_1B_FLOPS_PER_DOC = 10e15 / 10_000
OUR_PROXY_FLOPS_PER_DOC = 2e12 / 10_000


class OracleError(RuntimeError):
    """Base for oracle-plane failures. Subclasses RuntimeError so layers
    that already map RuntimeError to a 5xx keep working. Lives here (not
    in serve/) so the engine can catch it without importing the serving
    package (which imports the engine)."""


class OracleFault(OracleError):
    """A single invocation failed (drop, rate-limit, poison input).
    Retryable."""


class OracleTimeout(OracleFault):
    """An invocation exceeded its deadline. Retryable."""


class OracleUnavailable(OracleError):
    """The oracle plane gave up: retries/bisection exhausted or the
    circuit breaker is open. Carries the doc ids that were NOT labeled
    and an advisory retry-after horizon."""

    def __init__(self, message: str = "oracle unavailable", *,
                 docs: Sequence[int] = (), retry_after: float = 0.0,
                 breaker_open: bool = False):
        super().__init__(message)
        self.docs = tuple(int(d) for d in docs)
        self.retry_after = float(retry_after)
        self.breaker_open = bool(breaker_open)


class CachedOracle:
    """Memoizing wrapper: labels already purchased are never re-paid.
    The pipeline samples training, calibration and ambiguous-band labels
    independently; overlaps are common at high selectivity and should
    cost nothing.

    Thread-safe: the serving layer shares one ``CachedOracle`` per
    underlying oracle across every concurrent query session, so the
    miss-check and the purchase happen under one lock — two sessions
    racing on the same document can never both pay for it. ``calls`` /
    ``queried`` snapshot the inner oracle under the same lock, so they
    are mutually consistent even while purchases are in flight.

    Deliberate trade: holding the lock across ``inner.label`` means
    purchases for one oracle are serialized (and a slow round trip
    briefly blocks ``calls``/``stats``/``peek`` for that oracle). That
    is what makes at-most-once purchase a one-lock invariant;
    *concurrency* across asks is the layer above's job — the
    ``OracleBroker`` coalesces concurrent asks into one batched
    purchase instead of queueing on this lock, so the serialized
    section is one round trip per micro-batch, not per session.
    """

    def __init__(self, inner):
        self.inner = inner
        self._cache = {}
        self._lock = threading.Lock()
        self.hits = 0            # per-doc label asks served from cache
        self.purchases = 0       # inner label() invocations
        self.docs_purchased = 0  # docs actually paid for (sum of misses)

    @property
    def calls(self):
        with self._lock:
            return self.inner.calls

    @property
    def queried(self):
        with self._lock:
            return set(self.inner.queried)

    @property
    def cached_count(self):
        with self._lock:
            return len(self._cache)

    def cached_positive_rate(self) -> Optional[float]:
        """Mean of the labels already purchased (None while the cache is
        empty) — a free positive-rate estimate degraded-mode serving
        uses to place its proxy-score cut during an oracle outage."""
        with self._lock:
            if not self._cache:
                return None
            return float(np.mean([bool(v) for v in self._cache.values()]))

    def stats(self) -> dict:
        """One atomic snapshot of calls / queried / cache size / hit
        accounting (reading the properties separately can interleave
        with a concurrent purchase)."""
        with self._lock:
            return {"calls": self.inner.calls,
                    "queried": len(getattr(self.inner, "queried", ())),
                    "cached": len(self._cache),
                    "hits": self.hits,
                    "purchases": self.purchases,
                    "docs_purchased": self.docs_purchased}

    @property
    def flops_per_doc(self):
        return getattr(self.inner, "flops_per_doc", ORACLE_FLOPS_PER_DOC)

    def peek(self, indices) -> Sequence[int]:
        """Indices (deduped, first-appearance order) not yet cached.
        Advisory only — another thread may purchase them between peek
        and label; ``label`` re-checks under the lock."""
        with self._lock:
            out, seen = [], set()
            for i in np.asarray(indices, dtype=np.int64):
                i = int(i)
                if i not in self._cache and i not in seen:
                    seen.add(i)
                    out.append(i)
            return out

    def label(self, indices):
        indices = np.asarray(indices, dtype=np.int64)
        with self._lock:
            missing = []
            seen = set()
            for i in indices:
                i = int(i)
                if i not in self._cache and i not in seen:
                    seen.add(i)
                    missing.append(i)
            if missing:
                got = self.inner.label(np.asarray(missing, dtype=np.int64))
                for i, v in zip(missing, got):
                    self._cache[i] = bool(v)
                self.purchases += 1
                self.docs_purchased += len(missing)
            # per-doc hit accounting: every unique doc in the ask that
            # did NOT need a purchase was served from cache, whether or
            # not the ask was fully cached. Counted only after a
            # successful purchase so a raising inner leaves stats
            # describing completed asks only.
            self.hits += len({int(i) for i in indices}) - len(missing)
            return np.array([self._cache[int(i)] for i in indices],
                            dtype=bool)


class SimulatedOracle:
    """Ground-truth labeler with invocation accounting."""

    def __init__(self, labels: np.ndarray, flip_noise: float = 0.0,
                 seed: int = 0,
                 flops_per_doc: float = ORACLE_FLOPS_PER_DOC):
        self._labels = np.asarray(labels).astype(bool)
        self._rng = np.random.default_rng(seed)
        self.flip_noise = flip_noise
        self.flops_per_doc = flops_per_doc
        self.calls = 0
        self.queried = set()

    def label(self, indices: Sequence[int]) -> np.ndarray:
        indices = np.asarray(indices, dtype=np.int64)
        self.calls += len(indices)
        self.queried.update(int(i) for i in indices)
        out = self._labels[indices].copy()
        if self.flip_noise > 0:
            flips = self._rng.random(len(indices)) < self.flip_noise
            out = out ^ flips
        return out

    @property
    def total_flops(self) -> float:
        return self.calls * self.flops_per_doc

    def reset(self):
        self.calls = 0
        self.queried = set()


@dataclasses.dataclass
class LMOracleConfig:
    yes_token: int = 1
    no_token: int = 2
    max_doc_tokens: int = 64


class LMOracle:
    """LM-as-judge oracle over tokenized documents.

    verbalize(query_tokens, doc_tokens) builds the prompt; the label is
    logit(yes) > logit(no) at the final position.
    """

    def __init__(self, model, params, query_tokens: np.ndarray,
                 doc_tokens: np.ndarray, cfg: LMOracleConfig = LMOracleConfig()):
        import jax
        import jax.numpy as jnp
        self.model = model
        self.params = params
        self.cfg = cfg
        self.query_tokens = np.asarray(query_tokens)
        self.doc_tokens = np.asarray(doc_tokens)
        self.calls = 0

        def judge(params, tokens):
            logits, _ = model.forward(params, tokens)
            last = logits[:, -1]
            return last[:, cfg.yes_token] > last[:, cfg.no_token]

        self._judge = jax.jit(judge)
        self._jnp = jnp

    def _prompt(self, doc_idx: int) -> np.ndarray:
        doc = self.doc_tokens[doc_idx][: self.cfg.max_doc_tokens]
        return np.concatenate([self.query_tokens, doc])

    def label(self, indices: Sequence[int]) -> np.ndarray:
        indices = np.asarray(indices, dtype=np.int64)
        self.calls += len(indices)
        prompts = [self._prompt(int(i)) for i in indices]
        width = max(len(p) for p in prompts)
        batch = np.zeros((len(prompts), width), np.int32)
        for i, p in enumerate(prompts):
            batch[i, -len(p):] = p  # left-pad
        return np.asarray(self._judge(self.params, self._jnp.asarray(batch)))
