"""Oracle LLM interfaces.

* SimulatedOracle — planted ground-truth labels + optional flip noise +
  a FLOPs cost model (the container has no GPT-4o / GPU; the paper's own
  Table 2 reports cost in FLOPs, which we mirror). Counts invocations.
* LMOracle — runs one of the assigned-architecture LMs as a judge: scores
  a verbalized (query, document) pair by comparing yes/no token logits.
  Used by the end-to-end LM example; slow on CPU, so sized down there.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import numpy as np

# FLOPs cost model per document. Provenance: paper §6.2 (Table 2
# "computational cost" column) reports TOTAL FLOPs over a 10k-document
# collection of ~400-word documents; we normalize each per document.
#   oracle LLM (GPT-4o class)  >500 PFLOPs / 10k docs -> ~50 TFLOPs/doc
#   3B proxy-LLM baseline        27 PFLOPs / 10k docs
#   1B proxy-LLM baseline        10 PFLOPs / 10k docs
#   ScaleDoc MLP proxy           ~2 TFLOPs / 10k docs -> ~0.2 GFLOPs/doc
# (sanity check: ~2*params*tokens forward FLOPs at a few hundred tokens
# per document lands within ~2x of each row). benchmarks/ and
# QueryStats report cost in these units because the container has no
# GPT-4o; the ratios, not the absolute counts, carry the paper's story.
ORACLE_FLOPS_PER_DOC = 500e15 / 10_000
PROXY_LLM_3B_FLOPS_PER_DOC = 27e15 / 10_000
PROXY_LLM_1B_FLOPS_PER_DOC = 10e15 / 10_000
OUR_PROXY_FLOPS_PER_DOC = 2e12 / 10_000


class CachedOracle:
    """Memoizing wrapper: labels already purchased are never re-paid.
    The pipeline samples training, calibration and ambiguous-band labels
    independently; overlaps are common at high selectivity and should
    cost nothing."""

    def __init__(self, inner):
        self.inner = inner
        self._cache = {}

    @property
    def calls(self):
        return self.inner.calls

    @property
    def queried(self):
        return self.inner.queried

    @property
    def flops_per_doc(self):
        return getattr(self.inner, "flops_per_doc", ORACLE_FLOPS_PER_DOC)

    def label(self, indices):
        indices = np.asarray(indices, dtype=np.int64)
        missing = [int(i) for i in indices if int(i) not in self._cache]
        if missing:
            got = self.inner.label(np.asarray(missing, dtype=np.int64))
            for i, v in zip(missing, got):
                self._cache[i] = bool(v)
        return np.array([self._cache[int(i)] for i in indices], dtype=bool)


class SimulatedOracle:
    """Ground-truth labeler with invocation accounting."""

    def __init__(self, labels: np.ndarray, flip_noise: float = 0.0,
                 seed: int = 0,
                 flops_per_doc: float = ORACLE_FLOPS_PER_DOC):
        self._labels = np.asarray(labels).astype(bool)
        self._rng = np.random.default_rng(seed)
        self.flip_noise = flip_noise
        self.flops_per_doc = flops_per_doc
        self.calls = 0
        self.queried = set()

    def label(self, indices: Sequence[int]) -> np.ndarray:
        indices = np.asarray(indices, dtype=np.int64)
        self.calls += len(indices)
        self.queried.update(int(i) for i in indices)
        out = self._labels[indices].copy()
        if self.flip_noise > 0:
            flips = self._rng.random(len(indices)) < self.flip_noise
            out = out ^ flips
        return out

    @property
    def total_flops(self) -> float:
        return self.calls * self.flops_per_doc

    def reset(self):
        self.calls = 0
        self.queried = set()


@dataclasses.dataclass
class LMOracleConfig:
    yes_token: int = 1
    no_token: int = 2
    max_doc_tokens: int = 64


class LMOracle:
    """LM-as-judge oracle over tokenized documents.

    verbalize(query_tokens, doc_tokens) builds the prompt; the label is
    logit(yes) > logit(no) at the final position.
    """

    def __init__(self, model, params, query_tokens: np.ndarray,
                 doc_tokens: np.ndarray, cfg: LMOracleConfig = LMOracleConfig()):
        import jax
        import jax.numpy as jnp
        self.model = model
        self.params = params
        self.cfg = cfg
        self.query_tokens = np.asarray(query_tokens)
        self.doc_tokens = np.asarray(doc_tokens)
        self.calls = 0

        def judge(params, tokens):
            logits, _ = model.forward(params, tokens)
            last = logits[:, -1]
            return last[:, cfg.yes_token] > last[:, cfg.no_token]

        self._judge = jax.jit(judge)
        self._jnp = jnp

    def _prompt(self, doc_idx: int) -> np.ndarray:
        doc = self.doc_tokens[doc_idx][: self.cfg.max_doc_tokens]
        return np.concatenate([self.query_tokens, doc])

    def label(self, indices: Sequence[int]) -> np.ndarray:
        indices = np.asarray(indices, dtype=np.int64)
        self.calls += len(indices)
        prompts = [self._prompt(int(i)) for i in indices]
        width = max(len(p) for p in prompts)
        batch = np.zeros((len(prompts), width), np.int32)
        for i, p in enumerate(prompts):
            batch[i, -len(p):] = p  # left-pad
        return np.asarray(self._judge(self.params, self._jnp.asarray(batch)))
