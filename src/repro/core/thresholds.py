"""Threshold selection (paper §4.3, Algorithm 2).

Given calibrated class-conditional CDFs, select (l, r) minimizing the
unfiltered rate u(l, r) subject to Acc(l, r) >= alpha.

Accuracy model (F1, matching §4.4): with F+ = positive prior,
  FN(l) = F+ * CDF_P(l)              (positives auto-labeled negative)
  FP(r) = F- * (1 - CDF_N(r))        (negatives auto-labeled positive)
  TP    = F+ - FN(l)                 (oracle region is perfect)
  F1(l, r) = 2 TP / (2 TP + FP + FN)
Exact-match variant: Acc = 1 - FP - FN (for the BARGAIN comparison).

The frontier traversal is the linear-time staircase walk: starting from
(l0, r_s) — the tightest feasible lower bound at the most conservative
upper bound — repeatedly try to tighten r by one bin; when that violates
the constraint, loosen l by one bin (regaining slack). Every Pareto point
at bin granularity is visited once, so the argmin of u over the path is
the constrained optimum (validated against the O(B^2) brute force in
tests). This is our reading of Algorithm 2's pseudocode, whose published
`l + bins.size` steps have an (apparent) sign typo.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.core.calibration import Calibration


@dataclasses.dataclass
class ThresholdResult:
    l: float
    r: float
    unfiltered: float
    est_accuracy: float
    feasible: bool
    path_len: int = 0


def accuracy_est(calib: Calibration, l: float, r: float,
                 metric: str = "f1") -> float:
    fp_prior = calib.prior_pos
    fn_mass = fp_prior * calib.pdf_pos.cdf(l)
    fp_mass = (1 - fp_prior) * (1.0 - calib.pdf_neg.cdf(r))
    tp = fp_prior - fn_mass
    if metric == "exact":
        return float(1.0 - fp_mass - fn_mass)
    denom = 2 * tp + fp_mass + fn_mass
    return float(2 * tp / denom) if denom > 0 else 0.0


def unfiltered_est(calib: Calibration, l: float, r: float) -> float:
    p = calib.prior_pos
    mass = (p * (calib.pdf_pos.cdf(r) - calib.pdf_pos.cdf(l))
            + (1 - p) * (calib.pdf_neg.cdf(r) - calib.pdf_neg.cdf(l)))
    return float(max(mass, 0.0))


def select_thresholds(calib: Calibration, alpha: float,
                      metric: str = "f1",
                      margin: float = 0.0) -> ThresholdResult:
    """Linear frontier walk (Algorithm 2). ``margin`` tightens the
    constraint to Acc >= alpha + margin (Bernstein safety, §4.4)."""
    steps = calib.edges
    B = len(steps) - 1
    target = alpha + margin

    def acc(l, r):
        return accuracy_est(calib, l, r, metric)

    l_s, r_s = steps[0], steps[-1]
    if acc(l_s, r_s) < target:
        # even all-oracle cannot certify per the estimate (possible when
        # the prior estimate itself is off) -> send everything to oracle
        return ThresholdResult(l_s, r_s, 1.0, acc(l_s, r_s), False)

    # 1. tightest l0 with r = r_s
    i_l0 = 0
    for i in range(1, B + 1):
        if acc(steps[i], r_s) >= target:
            i_l0 = i
        else:
            break
    # 2. staircase walk from (l0, r_s) toward (l_s, r0)
    best = (unfiltered_est(calib, steps[i_l0], r_s), i_l0, B)
    il, ir = i_l0, B
    path = 1
    while ir > 0:
        if il > 0 and acc(steps[il], steps[ir - 1]) < target:
            il -= 1           # loosen l to regain slack
        else:
            if acc(steps[il], steps[ir - 1]) < target:
                break          # even l = l_s cannot support tighter r
            ir -= 1            # tighten r
        path += 1
        u = unfiltered_est(calib, steps[il], steps[ir])
        if u < best[0]:
            best = (u, il, ir)
    u, il, ir = best
    return ThresholdResult(float(steps[il]), float(steps[ir]), u,
                           acc(steps[il], steps[ir]), True, path)


def bootstrap_certify(sample_scores: np.ndarray, sample_labels: np.ndarray,
                      l: float, r: float, alpha: float, metric: str,
                      n_boot: int, conf: float,
                      rng: np.random.Generator) -> bool:
    """Resample the calibration sample; the pair (l, r) is certified when
    >= conf of resamples meet the accuracy target (oracle-perfect band)."""
    n = len(sample_scores)
    if n == 0:
        return False
    labels = sample_labels.astype(bool)
    ok = 0
    for _ in range(n_boot):
        idx = rng.integers(0, n, size=n)
        s, y = sample_scores[idx], labels[idx]
        fn = int(np.sum(y & (s < l)))
        fp = int(np.sum(~y & (s > r)))
        tp = int(y.sum()) - fn
        if metric == "exact":
            acc = 1.0 - (fp + fn) / n
        else:
            denom = 2 * tp + fp + fn
            acc = 2 * tp / denom if denom else 1.0
        ok += acc >= alpha
    return ok >= conf * n_boot


def select_thresholds_certified(calib: Calibration, alpha: float,
                                metric: str = "f1",
                                n_boot: int = 64, conf: float = 0.9,
                                max_margin: float = 0.08,
                                rng: Optional[np.random.Generator] = None
                                ) -> ThresholdResult:
    """Widen the selection target until the bootstrap certifies the chosen
    thresholds on the calibration sample (the robustness layer behind the
    paper's Fig. 12a accuracy-maintenance results)."""
    rng = rng or np.random.default_rng(0)
    if calib.sample_scores is None:
        raise ValueError("Calibration missing raw sample scores")
    margin = 0.0
    sel = select_thresholds(calib, alpha, metric, margin)
    while margin <= max_margin:
        sel = select_thresholds(calib, alpha, metric, margin)
        if not sel.feasible:
            break
        if bootstrap_certify(calib.sample_scores, calib.sample_labels,
                             sel.l, sel.r, alpha, metric, n_boot, conf, rng):
            return sel
        margin += 0.01
    return sel


def brute_force_thresholds(calib: Calibration, alpha: float,
                           metric: str = "f1",
                           margin: float = 0.0) -> ThresholdResult:
    """O(B^2) exhaustive reference (correctness oracle for Algorithm 2)."""
    steps = calib.edges
    target = alpha + margin
    best: Optional[Tuple[float, int, int]] = None
    for i in range(len(steps)):
        for j in range(i, len(steps)):
            if accuracy_est(calib, steps[i], steps[j], metric) >= target:
                u = unfiltered_est(calib, steps[i], steps[j])
                if best is None or u < best[0]:
                    best = (u, i, j)
    if best is None:
        return ThresholdResult(steps[0], steps[-1], 1.0,
                               accuracy_est(calib, steps[0], steps[-1],
                                            metric), False)
    u, i, j = best
    return ThresholdResult(float(steps[i]), float(steps[j]), u,
                           accuracy_est(calib, steps[i], steps[j], metric),
                           True)


def oracle_optimal_thresholds(scores: np.ndarray, labels: np.ndarray,
                              edges: np.ndarray, alpha: float,
                              metric: str = "f1") -> ThresholdResult:
    """Brute-force optimum computed on *ground-truth* labels — the
    'brute-force optimal cascade' used by the paper's Fig. 9 ablation."""
    labels = labels.astype(bool)
    n = len(scores)
    best = None
    for i in range(len(edges)):
        for j in range(i, len(edges)):
            l, r = edges[i], edges[j]
            auto_pos = scores > r
            auto_neg = scores < l
            fp = int(np.sum(auto_pos & ~labels))
            fn = int(np.sum(auto_neg & labels))
            tp = int(labels.sum()) - fn
            if metric == "exact":
                acc = 1.0 - (fp + fn) / max(n, 1)
            else:
                acc = 2 * tp / max(2 * tp + fp + fn, 1)
            if acc >= alpha:
                u = float(np.mean(~auto_pos & ~auto_neg))
                if best is None or u < best[0]:
                    best = (u, l, r, acc)
    if best is None:
        return ThresholdResult(0.0, 1.0, 1.0, 0.0, False)
    u, l, r, acc = best
    return ThresholdResult(float(l), float(r), u, float(acc), True)
