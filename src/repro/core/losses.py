"""ScaleDoc's three contrastive objectives (paper §3.2, Fig. 3).

All losses operate on *projected, L2-normalized* latents:
  z_q : (p,)   query anchor
  z_d : (n, p) documents in the mini-batch
  y   : (n,)   binary labels (1 = positive)

  L_qsim   (eq. 1): InfoNCE with the query as anchor — pulls positives
           toward the query, pushes negatives away (semantic monotonicity).
  L_supcon (eq. 2): supervised contrastive — intra-class clustering.
  L_polar  (eq. 3): bellwether polarization — per-batch bellwethers
           d_pos = argmin_{d+} sim(q, d),  d_neg = argmax_{d-} sim(q, d)
           anchor pulls that enlarge the inter-class margin (bipolarity).

Degenerate batches (no positives / no negatives) contribute 0 to the
affected terms (guarded with masked logsumexp).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.encoder import l2_normalize

NEG = -1e30


def _masked_lse(logits: jnp.ndarray, mask: jnp.ndarray,
                axis: int = -1) -> jnp.ndarray:
    """log sum_{i in mask} exp(logits_i); returns NEG if mask empty."""
    masked = jnp.where(mask, logits, NEG)
    return jax.nn.logsumexp(masked, axis=axis)


def qsim_loss(z_q: jnp.ndarray, z_d: jnp.ndarray, y: jnp.ndarray,
              tau: float, variant: str = "perpos") -> jnp.ndarray:
    """Eq. (1) InfoNCE with the query as anchor.

    variant="perpos" (default): mean over positives of
        -log( e^{sim_i/tau} / sum_all e^{sim/tau} )
    — the DPR [20] form the paper builds on. The literal eq. (1) puts the
    positive sum *inside* the log ("sum" variant); with multiple positives
    per batch that objective is satisfied by a single well-placed positive
    and demonstrably under-trains (see tests/test_losses.py and
    EXPERIMENTS.md §Paper-validation), so we default to the DPR form and
    keep "sum" for the ablation.
    """
    zq = l2_normalize(z_q)
    zd = l2_normalize(z_d)
    sims = zd @ zq / tau                           # (n,)
    pos = y > 0.5
    any_pos = jnp.any(pos)
    lse_all = jax.nn.logsumexp(sims)
    if variant == "sum":
        lse_pos = _masked_lse(sims, pos)
        loss = -(lse_pos - lse_all)
    else:
        per = -(sims - lse_all)
        loss = (jnp.sum(jnp.where(pos, per, 0.0))
                / jnp.maximum(jnp.sum(pos), 1))
    return jnp.where(any_pos, loss, 0.0)


def supcon_loss(z_d: jnp.ndarray, y: jnp.ndarray, tau: float) -> jnp.ndarray:
    """Eq. (2): for each anchor i,
    -1/|U(i)| log( sum_{p in U(i)} e^{sim_ip/tau} / sum_{k in A(i)} ... )."""
    n = z_d.shape[0]
    zd = l2_normalize(z_d)
    sims = zd @ zd.T / tau                         # (n, n)
    eye = jnp.eye(n, dtype=bool)
    same = (y[:, None] > 0.5) == (y[None, :] > 0.5)
    u_mask = same & ~eye                            # U(i)
    a_mask = ~eye                                   # A(i)
    u_count = jnp.sum(u_mask, axis=1)
    lse_u = _masked_lse(sims, u_mask, axis=1)
    lse_a = _masked_lse(sims, a_mask, axis=1)
    per_anchor = -(lse_u - lse_a) / jnp.maximum(u_count, 1)
    valid = u_count > 0
    return jnp.sum(jnp.where(valid, per_anchor, 0.0)) / jnp.maximum(
        jnp.sum(valid), 1)


def polar_loss(z_q: jnp.ndarray, z_d: jnp.ndarray, y: jnp.ndarray,
               tau: float) -> jnp.ndarray:
    """Eq. (3): bellwether-anchored bipolarization."""
    zq = l2_normalize(z_q)
    zd = l2_normalize(z_d)
    sim_q = zd @ zq                                 # (n,)
    pos = y > 0.5
    neg = ~pos
    any_pos = jnp.any(pos)
    any_neg = jnp.any(neg)

    # bellwethers: weakest positive / hardest negative w.r.t. the query
    pos_scores = jnp.where(pos, sim_q, jnp.inf)
    neg_scores = jnp.where(neg, sim_q, -jnp.inf)
    i_pos = jnp.argmin(pos_scores)
    i_neg = jnp.argmax(neg_scores)
    z_bp = zd[i_pos]                                # d_pos
    z_bn = zd[i_neg]                                # d_neg

    sims_bp = zd @ z_bp / tau
    sims_bn = zd @ z_bn / tau
    loss_p = -(_masked_lse(sims_bp, pos) - jax.nn.logsumexp(sims_bp))
    loss_n = -(_masked_lse(sims_bn, neg) - jax.nn.logsumexp(sims_bn))
    return (jnp.where(any_pos, loss_p, 0.0)
            + jnp.where(any_neg, loss_n, 0.0))


def phase1_loss(z_q, z_d, y, tau, variant: str = "perpos"):
    return qsim_loss(z_q, z_d, y, tau, variant)


def phase2_loss(z_q, z_d, y, tau, lam):
    """L2 = lam * L_supcon + (1 - lam) * L_polar (paper §5, lam=0.2)."""
    return (lam * supcon_loss(z_d, y, tau)
            + (1.0 - lam) * polar_loss(z_q, z_d, y, tau))
