"""Two-phase contrastive training of the query-aware proxy (paper §3.2, §5).

Given a small oracle-labeled sample of document embeddings, trains the
lightweight encoder:
  Phase 1: L_qsim only              -> semantic monotonicity
  Phase 2: lam*L_supcon + (1-lam)*L_polar -> bipolarity

Implementation details from paper §5:
  * fallback-style rebalancing: if the labeled sample is heavily skewed,
    augment the minority class with Gaussian-noised copies of its
    embeddings;
  * mini-batches contain the query embedding + documents; the projector
    head exists only during training;
  * losses are computed on projector outputs, scores on encoder outputs.

The train step is jit-compiled once and reused across steps; data-parallel
execution over the `data` mesh axis happens transparently when the inputs
are sharded (pure jnp ops — pjit handles the rest).
"""
from __future__ import annotations

import functools
from typing import Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import OptimizerConfig, ProxyConfig
from repro.core import losses
from repro.core.encoder import (decision_scores, encoder_apply, encoder_init,
                                projector_apply)
from repro.optimizer import adamw


class ProxyTrainResult(NamedTuple):
    params: Dict
    phase1_losses: np.ndarray
    phase2_losses: np.ndarray


def rebalance(key, embeds: np.ndarray, labels: np.ndarray,
              cfg: ProxyConfig) -> Tuple[np.ndarray, np.ndarray]:
    """Fallback rebalancing: Gaussian-noise augmentation of the minority."""
    labels = labels.astype(np.int32)
    n = len(labels)
    n_pos = int(labels.sum())
    n_neg = n - n_pos
    if n == 0 or min(n_pos, n_neg) >= cfg.rebalance_min_frac * n:
        return embeds, labels
    if n_pos == 0 or n_neg == 0:
        # degenerate sample: nothing to mirror — caller handles
        return embeds, labels
    minority = 1 if n_pos < n_neg else 0
    src = embeds[labels == minority]
    need = int(cfg.rebalance_min_frac * n) - len(src)
    if need <= 0:
        return embeds, labels
    rng = np.random.default_rng(np.asarray(key)[-1])
    idx = rng.integers(0, len(src), size=need)
    noise = rng.normal(0.0, cfg.rebalance_noise, size=(need, embeds.shape[1]))
    aug = src[idx] + noise.astype(embeds.dtype)
    embeds = np.concatenate([embeds, aug], axis=0)
    labels = np.concatenate([labels, np.full(need, minority, labels.dtype)])
    return embeds, labels


@functools.partial(jax.jit, static_argnames=("cfg", "phase", "opt_cfg"))
def _train_step(params, opt_state, key, e_q, e_batch, y_batch, *,
                cfg: ProxyConfig, phase: int, opt_cfg: OptimizerConfig):
    if cfg.aug_noise > 0:
        e_batch = e_batch + cfg.aug_noise * jax.random.normal(
            key, e_batch.shape, e_batch.dtype)

    def loss_fn(p):
        z_q = projector_apply(p, encoder_apply(p, e_q))
        z_d = projector_apply(p, encoder_apply(p, e_batch))
        if phase == 1:
            return losses.phase1_loss(z_q, z_d, y_batch, cfg.temperature,
                                      cfg.qsim_variant)
        return losses.phase2_loss(z_q, z_d, y_batch, cfg.temperature,
                                  cfg.lambda_supcon)

    loss, grads = jax.value_and_grad(loss_fn)(params)
    params, opt_state, _ = adamw.apply_updates(opt_cfg, params, grads,
                                               opt_state)
    return params, opt_state, loss


def train_proxy(key, e_q: jnp.ndarray, embeds: jnp.ndarray,
                labels: jnp.ndarray, cfg: ProxyConfig) -> ProxyTrainResult:
    """Train the proxy on an oracle-labeled sample.

    e_q: (D,) query embedding; embeds: (n, D); labels: (n,) {0,1}.
    """
    kinit, kbal, kbatch = jax.random.split(key, 3)
    if cfg.rebalance:
        embeds_np, labels_np = rebalance(kbal, np.asarray(embeds),
                                         np.asarray(labels), cfg)
    else:
        embeds_np, labels_np = np.asarray(embeds), np.asarray(labels)
    embeds = jnp.asarray(embeds_np)
    labels = jnp.asarray(labels_np.astype(np.float32))
    n = embeds.shape[0]

    params = encoder_init(kinit, cfg)
    opt_cfg = OptimizerConfig(lr=cfg.lr, warmup_steps=5,
                              total_steps=cfg.phase1_steps + cfg.phase2_steps,
                              schedule="cosine",
                              weight_decay=cfg.weight_decay,
                              grad_clip=1.0)
    opt_state = adamw.init(opt_cfg, params)
    bs = min(cfg.batch_size, n)

    rng = np.random.default_rng(int(jax.random.randint(
        kbatch, (), 0, 2**31 - 1)))

    def batches(steps):
        for _ in range(steps):
            idx = rng.choice(n, size=bs, replace=(bs > n))
            yield jnp.asarray(idx)

    key = kbatch
    p1_losses, p2_losses = [], []
    for idx in batches(cfg.phase1_steps):
        key, kstep = jax.random.split(key)
        params, opt_state, loss = _train_step(
            params, opt_state, kstep, e_q, embeds[idx], labels[idx],
            cfg=cfg, phase=1, opt_cfg=opt_cfg)
        p1_losses.append(float(loss))
    for idx in batches(cfg.phase2_steps):
        key, kstep = jax.random.split(key)
        params, opt_state, loss = _train_step(
            params, opt_state, kstep, e_q, embeds[idx], labels[idx],
            cfg=cfg, phase=2, opt_cfg=opt_cfg)
        p2_losses.append(float(loss))

    return ProxyTrainResult(params, np.asarray(p1_losses),
                            np.asarray(p2_losses))


def train_proxy_variant(key, e_q, embeds, labels, cfg: ProxyConfig,
                        variant: str) -> Dict:
    """Ablation variants for the paper's Fig. 9/11: 'qsim' (phase 1 only),
    'qsim+supcon', 'qsim+polar', 'full', or 'mlp' (binary classifier)."""
    if variant == "full":
        return train_proxy(key, e_q, embeds, labels, cfg).params
    if variant == "mlp":
        return _train_mlp_classifier(key, embeds, labels, cfg)

    import dataclasses as _dc
    kinit, kbatch = jax.random.split(key)
    params = encoder_init(kinit, cfg)
    opt_cfg = OptimizerConfig(lr=cfg.lr, warmup_steps=5,
                              total_steps=cfg.phase1_steps + cfg.phase2_steps,
                              schedule="cosine",
                              weight_decay=cfg.weight_decay)
    opt_state = adamw.init(opt_cfg, params)
    labels_f = jnp.asarray(np.asarray(labels), jnp.float32)
    embeds = jnp.asarray(embeds)
    n = embeds.shape[0]
    bs = min(cfg.batch_size, n)
    rng = np.random.default_rng(0)

    lam_map = {"qsim": None, "qsim+supcon": 1.0, "qsim+polar": 0.0}
    lam = lam_map[variant]
    kloop = kbatch
    for step in range(cfg.phase1_steps + cfg.phase2_steps):
        idx = jnp.asarray(rng.choice(n, size=bs, replace=(bs > n)))
        phase = 1 if (step < cfg.phase1_steps or lam is None) else 2
        cfg_used = cfg if lam is None else _dc.replace(cfg, lambda_supcon=lam)
        kloop, kstep = jax.random.split(kloop)
        params, opt_state, _ = _train_step(
            params, opt_state, kstep, e_q, embeds[idx], labels_f[idx],
            cfg=cfg_used, phase=phase, opt_cfg=opt_cfg)
    return params


def _train_mlp_classifier(key, embeds, labels, cfg: ProxyConfig) -> Dict:
    """Baseline: plain MLP binary classifier on embeddings (paper Fig. 9
    'MLP'). Returns params usable with mlp_classifier_scores."""
    from repro.models.common import dense_init
    k1, k2, k3 = jax.random.split(key, 3)
    params = {"w1": dense_init(k1, cfg.embed_dim, (cfg.hidden_dim,),
                               jnp.float32),
              "b1": jnp.zeros((cfg.hidden_dim,)),
              "w2": dense_init(k2, cfg.hidden_dim, (cfg.hidden_dim,),
                               jnp.float32),
              "b2": jnp.zeros((cfg.hidden_dim,)),
              "w3": dense_init(k3, cfg.hidden_dim, (1,), jnp.float32),
              "b3": jnp.zeros((1,))}
    opt_cfg = OptimizerConfig(lr=cfg.lr, warmup_steps=5,
                              total_steps=cfg.phase1_steps + cfg.phase2_steps,
                              weight_decay=0.0)
    opt_state = adamw.init(opt_cfg, params)
    embeds = jnp.asarray(embeds)
    y = jnp.asarray(np.asarray(labels), jnp.float32)
    n = embeds.shape[0]
    bs = min(cfg.batch_size, n)
    rng = np.random.default_rng(0)

    @jax.jit
    def step_fn(params, opt_state, xb, yb):
        def loss_fn(p):
            h = jax.nn.gelu(xb @ p["w1"] + p["b1"])
            h = jax.nn.gelu(h @ p["w2"] + p["b2"])
            logit = (h @ p["w3"] + p["b3"])[:, 0]
            return jnp.mean(jnp.maximum(logit, 0) - logit * yb
                            + jnp.log1p(jnp.exp(-jnp.abs(logit))))
        loss, grads = jax.value_and_grad(loss_fn)(params)
        params, opt_state, _ = adamw.apply_updates(opt_cfg, params, grads,
                                                   opt_state)
        return params, opt_state, loss

    for _ in range(cfg.phase1_steps + cfg.phase2_steps):
        idx = jnp.asarray(rng.choice(n, size=bs, replace=(bs > n)))
        params, opt_state, _ = step_fn(params, opt_state, embeds[idx], y[idx])
    return params


def mlp_classifier_scores(params, embeds) -> jnp.ndarray:
    h = jax.nn.gelu(embeds @ params["w1"] + params["b1"])
    h = jax.nn.gelu(h @ params["w2"] + params["b2"])
    return jax.nn.sigmoid((h @ params["w3"] + params["b3"])[:, 0])
