"""Two-phase contrastive training of the query-aware proxy (paper §3.2, §5).

Given a small oracle-labeled sample of document embeddings, trains the
lightweight encoder:
  Phase 1: L_qsim only              -> semantic monotonicity
  Phase 2: lam*L_supcon + (1-lam)*L_polar -> bipolarity

Implementation details from paper §5:
  * fallback-style rebalancing: if the labeled sample is heavily skewed,
    augment the minority class with Gaussian-noised copies of its
    embeddings;
  * mini-batches contain the query embedding + documents; the projector
    head exists only during training;
  * losses are computed on projector outputs, scores on encoder outputs.

Execution model (the online-latency hot path, ScaleDoc §5): the whole
two-phase run is ONE compiled device program — ``lax.scan`` over training
steps with on-device batch sampling (`jax.random` keys folded per step),
params/opt-state buffers donated to the jit, and the full loss trace
returned as a single array, so a run costs one dispatch and one
device->host sync instead of one of each per step. Phase-2 losses route
through ``repro.kernels.contrastive`` (Pallas forward on TPU, reference
VJP backward). ``train_proxy_multi`` vmaps the same scanned core over Q
stacked (e_q, sample, labels) sets so a compound predicate's leaves all
train in one program; ragged samples are zero-padded to a shared bucket
and a per-leaf ``n_valid`` bounds the batch sampler, which makes padding
invisible to the math — multi results are identical to Q single calls.

Batch indices are drawn per step as ``randint(fold_in(key, t), (bs,), 0,
n_valid)`` (uniform with replacement). The pre-scan per-step host loop
survives as ``method="steps"`` — same key schedule, same batches, same
math — as the parity oracle and the dispatch-overhead baseline that
benchmarks/bench_training.py measures against.
"""
from __future__ import annotations

import functools
from typing import Dict, List, NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.config.base import OptimizerConfig, ProxyConfig
from repro.core import losses
from repro.core.encoder import encoder_apply, encoder_init, projector_apply
from repro.kernels.contrastive import ops as contrastive_ops
from repro.optimizer import adamw


class ProxyTrainResult(NamedTuple):
    params: Dict
    phase1_losses: np.ndarray
    phase2_losses: np.ndarray


class ProxyTrainResultMulti(NamedTuple):
    """Q proxies trained in one compiled program. ``params`` leaves carry
    a leading (Q,) axis; use :func:`unstack_params` for per-proxy trees."""
    params: Dict
    phase1_losses: np.ndarray   # (Q, phase1_steps)
    phase2_losses: np.ndarray   # (Q, phase2_steps)


def _key_seed(key) -> int:
    """Host uint32 seed from a PRNG key — handles both typed PRNG key
    arrays (where np.asarray raises) and legacy uint32 vector keys (kept
    byte-compatible with the pre-typed-key seeding)."""
    data = key
    dtype = getattr(key, "dtype", None)
    if dtype is not None and jnp.issubdtype(dtype, jax.dtypes.prng_key):
        data = jax.random.key_data(key)
    return int(np.asarray(data).ravel()[-1])


def rebalance(key, embeds: np.ndarray, labels: np.ndarray,
              cfg: ProxyConfig) -> Tuple[np.ndarray, np.ndarray]:
    """Fallback rebalancing: Gaussian-noise augmentation of the minority."""
    labels = labels.astype(np.int32)
    n = len(labels)
    n_pos = int(labels.sum())
    n_neg = n - n_pos
    if n == 0 or min(n_pos, n_neg) >= cfg.rebalance_min_frac * n:
        return embeds, labels
    if n_pos == 0 or n_neg == 0:
        # degenerate sample: nothing to mirror — caller handles
        return embeds, labels
    minority = 1 if n_pos < n_neg else 0
    src = embeds[labels == minority]
    need = int(cfg.rebalance_min_frac * n) - len(src)
    if need <= 0:
        return embeds, labels
    rng = np.random.default_rng(_key_seed(key))
    idx = rng.integers(0, len(src), size=need)
    noise = rng.normal(0.0, cfg.rebalance_noise, size=(need, embeds.shape[1]))
    aug = src[idx] + noise.astype(embeds.dtype)
    embeds = np.concatenate([embeds, aug], axis=0)
    labels = np.concatenate([labels, np.full(need, minority, labels.dtype)])
    return embeds, labels


# ---------------------------------------------------------------------------
# loss selection (static at trace time)
# ---------------------------------------------------------------------------

def _project(params, x):
    return projector_apply(params, encoder_apply(params, x))


def _loss_phase1(params, e_q, xb, yb, cfg: ProxyConfig):
    return losses.phase1_loss(_project(params, e_q), _project(params, xb),
                              yb, cfg.temperature, cfg.qsim_variant)


def _loss_phase2(params, e_q, xb, yb, cfg: ProxyConfig):
    return contrastive_ops.phase2_loss(
        _project(params, e_q), _project(params, xb), yb,
        cfg.temperature, cfg.lambda_supcon, cfg.contrastive_impl)


def _loss_mlp(params, e_q, xb, yb, cfg: ProxyConfig):
    del e_q
    h = jax.nn.gelu(xb @ params["w1"] + params["b1"])
    h = jax.nn.gelu(h @ params["w2"] + params["b2"])
    logit = (h @ params["w3"] + params["b3"])[:, 0]
    return jnp.mean(jnp.maximum(logit, 0) - logit * yb
                    + jnp.log1p(jnp.exp(-jnp.abs(logit))))


# kind -> (phase-1 loss, phase-2 loss, apply Gaussian batch augmentation)
_KINDS = {
    "two_phase": (_loss_phase1, _loss_phase2, True),
    "mlp": (_loss_mlp, _loss_mlp, False),
}


def _train_core(params, ktrain, e_q, embeds, labels, n_valid, *,
                cfg: ProxyConfig, opt_cfg: OptimizerConfig, kind: str,
                bs: int):
    """The whole two-phase run as one traced program: two back-to-back
    scans (one per phase) over a shared global step counter ``t`` whose
    fold_in defines the batch/noise key schedule.

    All per-step RNG (batch indices, augmentation noise) is drawn in one
    vmapped pass over the step counter before the scans — bitwise the
    same values the scanned body would draw (vmap of threefry is exact),
    but as a handful of wide kernels instead of T small sequential
    threefry chains; on CPU this is a large share of the per-step time
    for small proxies. The gather rides along in the same pass, so the
    scan body is left with just loss + update over precomputed batches.
    """
    loss1, loss2, use_aug = _KINDS[kind]
    total = cfg.phase1_steps + cfg.phase2_steps
    aug = use_aug and cfg.aug_noise > 0

    def draws(t):
        kstep = jax.random.fold_in(ktrain, t)
        kb, kn = jax.random.split(kstep)
        idx = jax.random.randint(kb, (bs,), 0, n_valid)
        xb = jnp.take(embeds, idx, axis=0)
        if aug:
            xb = xb + cfg.aug_noise * jax.random.normal(kn, xb.shape,
                                                        xb.dtype)
        return xb, jnp.take(labels, idx, axis=0)

    xs_all, ys_all = jax.vmap(draws)(jnp.arange(total))   # (T, bs, D), (T, bs)

    opt_state = adamw.init(opt_cfg, params)

    def phase_scan(params, opt_state, t0, steps, loss_fn):
        def body(carry, batch):
            params, opt_state = carry
            xb, yb = batch
            loss, grads = jax.value_and_grad(
                lambda p: loss_fn(p, e_q, xb, yb, cfg))(params)
            params, opt_state = adamw.update(opt_cfg, params, grads,
                                             opt_state)
            return (params, opt_state), loss
        (params, opt_state), trace = jax.lax.scan(
            body, (params, opt_state),
            (xs_all[t0:t0 + steps], ys_all[t0:t0 + steps]))
        return params, opt_state, trace

    params, opt_state, l1 = phase_scan(params, opt_state, 0,
                                       cfg.phase1_steps, loss1)
    params, opt_state, l2 = phase_scan(params, opt_state, cfg.phase1_steps,
                                       cfg.phase2_steps, loss2)
    return params, l1, l2


@functools.lru_cache(maxsize=None)
def _compiled_trainer(cfg: ProxyConfig, opt_cfg: OptimizerConfig, kind: str,
                      bs: int, multi: bool, donate: bool):
    """jit (optionally vmapped over a leading Q axis) of ``_train_core``.

    ``donate=False`` on backends without donation support (CPU) avoids a
    warning; elsewhere the params/opt-state buffers alias in place."""
    fn = functools.partial(_train_core, cfg=cfg, opt_cfg=opt_cfg, kind=kind,
                           bs=bs)
    if multi:
        fn = jax.vmap(fn)
    return jax.jit(fn, donate_argnums=(0,) if donate else ())


def _donate() -> bool:
    return jax.default_backend() not in ("cpu",)


def _bucket(n: int) -> int:
    """Pad target for the labeled sample: next power of two (>= 64).

    The compiled trainer specializes on the padded shape, so bucketing
    bounds recompilation at one program per octave of sample size; the
    traced ``n_valid`` keeps the batch sampler exact, so padding never
    changes results."""
    m = 64
    while m < n:
        m *= 2
    return m


def _pad_sample(embeds: np.ndarray, labels: np.ndarray,
                pad_to: int) -> Tuple[jnp.ndarray, jnp.ndarray, int]:
    n = embeds.shape[0]
    if n < pad_to:
        embeds = np.concatenate(
            [embeds, np.zeros((pad_to - n, embeds.shape[1]), embeds.dtype)])
        labels = np.concatenate([labels, np.zeros(pad_to - n, labels.dtype)])
    return (jnp.asarray(embeds), jnp.asarray(labels.astype(np.float32)),
            n)


def _proxy_opt_cfg(cfg: ProxyConfig, weight_decay: float = None
                   ) -> OptimizerConfig:
    wd = cfg.weight_decay if weight_decay is None else weight_decay
    return OptimizerConfig(lr=cfg.lr, warmup_steps=5,
                           total_steps=cfg.phase1_steps + cfg.phase2_steps,
                           schedule="cosine", weight_decay=wd,
                           grad_clip=1.0)


def _prepare(kbal, embeds, labels, cfg: ProxyConfig, pad_to: int = 0):
    embeds_np, labels_np = np.asarray(embeds), np.asarray(labels)
    if cfg.rebalance:
        embeds_np, labels_np = rebalance(kbal, embeds_np, labels_np, cfg)
    return _pad_sample(embeds_np, labels_np,
                       pad_to or _bucket(embeds_np.shape[0]))


def train_proxy(key, e_q: jnp.ndarray, embeds: jnp.ndarray,
                labels: jnp.ndarray, cfg: ProxyConfig, *,
                method: str = "scan") -> ProxyTrainResult:
    """Train the proxy on an oracle-labeled sample.

    e_q: (D,) query embedding; embeds: (n, D); labels: (n,) {0,1}.

    ``method="scan"`` (default) runs the whole two-phase schedule as one
    compiled device program; ``method="steps"`` dispatches one jitted
    step at a time from the host (the pre-scan trainer — kept as the
    parity/benchmark baseline; same keys, same batches, same math).
    """
    kinit, kbal, ktrain = jax.random.split(key, 3)
    embeds_d, labels_d, n_valid = _prepare(kbal, embeds, labels, cfg)
    params = encoder_init(kinit, cfg)
    opt_cfg = _proxy_opt_cfg(cfg)
    e_q = jnp.asarray(e_q)
    bs = cfg.batch_size
    nv = jnp.asarray(n_valid, jnp.int32)
    kind = "two_phase"

    if method == "scan":
        fn = _compiled_trainer(cfg, opt_cfg, kind, bs, multi=False,
                               donate=_donate())
        params, l1, l2 = fn(params, ktrain, e_q, embeds_d, labels_d, nv)
        return ProxyTrainResult(params, np.asarray(l1), np.asarray(l2))

    if method != "steps":
        raise ValueError(f"unknown method {method!r}")
    opt_state = adamw.init(opt_cfg, params)
    p1_losses, p2_losses = [], []
    for t in range(cfg.phase1_steps + cfg.phase2_steps):
        phase2 = t >= cfg.phase1_steps
        # the PR-2 host-loop structure: batch sampling and the gather are
        # separate dispatches outside the step jit, and every step ends
        # in a device->host float(loss) sync — the overhead the scanned
        # path collapses into one program
        kstep = jax.random.fold_in(ktrain, t)
        kb, kn = jax.random.split(kstep)
        idx = jax.random.randint(kb, (bs,), 0, nv)
        params, opt_state, loss = _train_step(
            params, opt_state, kn, e_q, embeds_d[idx], labels_d[idx],
            cfg=cfg, opt_cfg=opt_cfg, kind=kind, phase2=phase2)
        (p2_losses if phase2 else p1_losses).append(float(loss))
    return ProxyTrainResult(params, np.asarray(p1_losses),
                            np.asarray(p2_losses))


@functools.partial(jax.jit,
                   static_argnames=("cfg", "opt_cfg", "kind", "phase2"))
def _train_step(params, opt_state, knoise, e_q, xb, yb, *,
                cfg: ProxyConfig, opt_cfg: OptimizerConfig, kind: str,
                phase2: bool):
    """One step of the ``method="steps"`` baseline: identical math to one
    iteration of the scanned body, dispatched (and synced) per step."""
    loss1, loss2, use_aug = _KINDS[kind]
    loss_fn = loss2 if phase2 else loss1
    if use_aug and cfg.aug_noise > 0:
        xb = xb + cfg.aug_noise * jax.random.normal(knoise, xb.shape,
                                                    xb.dtype)
    loss, grads = jax.value_and_grad(
        lambda p: loss_fn(p, e_q, xb, yb, cfg))(params)
    params, opt_state = adamw.update(opt_cfg, params, grads, opt_state)
    return params, opt_state, loss


@functools.lru_cache(maxsize=None)
def _compiled_multi_init(cfg: ProxyConfig):
    """One jitted program that splits Q keys and initializes Q encoders
    (vmapped — bitwise the values per-leaf ``split`` + ``encoder_init``
    would produce). Eagerly re-tracing this per call costs milliseconds
    of small dispatches, which is real money next to a ~100ms train."""
    def init(keys):
        def one(k):
            kinit, kbal, ktrain = jax.random.split(k, 3)
            return encoder_init(kinit, cfg), kbal, ktrain
        return jax.vmap(one)(keys)
    return jax.jit(init)


def unstack_params(stacked: Dict) -> List[Dict]:
    """Split a ``train_proxy_multi`` stacked param tree into Q trees."""
    q = jax.tree.leaves(stacked)[0].shape[0]
    return [jax.tree.map(lambda x: x[i], stacked) for i in range(q)]


def train_proxy_multi(keys, e_qs, samples: Sequence, labels: Sequence,
                      cfg: ProxyConfig) -> ProxyTrainResultMulti:
    """Train Q independent proxies in ONE compiled program.

    keys: Q PRNG keys; e_qs: (Q, D) query embeddings; samples[i]:
    (n_i, D) labeled embeddings; labels[i]: (n_i,) {0,1}. Ragged sample
    sizes are zero-padded to a shared bucket; a per-proxy ``n_valid``
    bounds the on-device batch sampler, so each lane draws exactly the
    batches a standalone ``train_proxy(keys[i], ...)`` call would — the
    vmapped run returns identical params, just without Q separate
    dispatch/compile round-trips.
    """
    q = len(samples)
    assert q == len(labels) and q == len(keys)
    params0, kbals, ktrain = _compiled_multi_init(cfg)(
        jnp.stack([jnp.asarray(k) for k in keys]))
    balanced = []
    for i, (s, y) in enumerate(zip(samples, labels)):
        e_np, y_np = np.asarray(s), np.asarray(y)
        if cfg.rebalance:
            e_np, y_np = rebalance(kbals[i], e_np, y_np, cfg)
        balanced.append((e_np, y_np))
    pad_to = _bucket(max(e.shape[0] for e, _ in balanced))
    n_valid = jnp.asarray([e.shape[0] for e, _ in balanced], jnp.int32)
    embeds_np = np.zeros((q, pad_to, balanced[0][0].shape[1]), np.float32)
    labels_np = np.zeros((q, pad_to), np.float32)
    for i, (e, y) in enumerate(balanced):
        embeds_np[i, :e.shape[0]] = e
        labels_np[i, :y.shape[0]] = y
    embeds_d, labels_d = jnp.asarray(embeds_np), jnp.asarray(labels_np)
    opt_cfg = _proxy_opt_cfg(cfg)
    e_qs = jnp.asarray(e_qs)

    fn = _compiled_trainer(cfg, opt_cfg, "two_phase", cfg.batch_size,
                           multi=True, donate=_donate())
    params, l1, l2 = fn(params0, ktrain, e_qs, embeds_d, labels_d, n_valid)
    return ProxyTrainResultMulti(params, np.asarray(l1), np.asarray(l2))


def train_proxy_variant(key, e_q, embeds, labels, cfg: ProxyConfig,
                        variant: str, *, method: str = "scan") -> Dict:
    """Ablation variants for the paper's Fig. 9/11: 'qsim' (phase 1 only),
    'qsim+supcon', 'qsim+polar', 'full', or 'mlp' (binary classifier).

    All variants ride the scanned trainer: they are expressed as config
    rewrites of the same compiled two-phase core (rebalancing stays off
    for the partial objectives, matching the original ablation setup).
    """
    import dataclasses as _dc
    if variant == "full":
        return train_proxy(key, e_q, embeds, labels, cfg,
                           method=method).params
    if variant == "mlp":
        return _train_mlp_classifier(key, embeds, labels, cfg,
                                     method=method)
    rewrites = {
        "qsim": dict(phase1_steps=cfg.phase1_steps + cfg.phase2_steps,
                     phase2_steps=0),
        "qsim+supcon": dict(lambda_supcon=1.0),
        "qsim+polar": dict(lambda_supcon=0.0),
    }
    cfg_v = _dc.replace(cfg, rebalance=False, **rewrites[variant])
    return train_proxy(key, e_q, embeds, labels, cfg_v,
                       method=method).params


def _train_mlp_classifier(key, embeds, labels, cfg: ProxyConfig, *,
                          method: str = "scan") -> Dict:
    """Baseline: plain MLP binary classifier on embeddings (paper Fig. 9
    'MLP'). Returns params usable with mlp_classifier_scores. Runs on the
    same scanned core as the proxy, with the BCE loss swapped in."""
    from repro.models.common import dense_init
    import dataclasses as _dc
    k1, k2, k3, ktrain = jax.random.split(key, 4)
    params = {"w1": dense_init(k1, cfg.embed_dim, (cfg.hidden_dim,),
                               jnp.float32),
              "b1": jnp.zeros((cfg.hidden_dim,)),
              "w2": dense_init(k2, cfg.hidden_dim, (cfg.hidden_dim,),
                               jnp.float32),
              "b2": jnp.zeros((cfg.hidden_dim,)),
              "w3": dense_init(k3, cfg.hidden_dim, (1,), jnp.float32),
              "b3": jnp.zeros((1,))}
    opt_cfg = _proxy_opt_cfg(cfg, weight_decay=0.0)
    cfg_m = _dc.replace(cfg, rebalance=False)
    e_q = jnp.zeros((np.asarray(embeds).shape[1],), jnp.float32)
    # reuse train_proxy's driver with the classifier loss; the ktrain-only
    # key split there would diverge from this function's historical
    # 4-way split, so drive the compiled core directly
    embeds_d, labels_d, n_valid = _prepare(None, embeds, labels, cfg_m)
    if method == "scan":
        fn = _compiled_trainer(cfg_m, opt_cfg, "mlp", cfg.batch_size,
                               multi=False, donate=_donate())
        params, _, _ = fn(params, ktrain, e_q, embeds_d, labels_d,
                          jnp.asarray(n_valid, jnp.int32))
        return params
    opt_state = adamw.init(opt_cfg, params)
    nv = jnp.asarray(n_valid, jnp.int32)
    for t in range(cfg.phase1_steps + cfg.phase2_steps):
        kstep = jax.random.fold_in(ktrain, t)
        kb, kn = jax.random.split(kstep)
        idx = jax.random.randint(kb, (cfg.batch_size,), 0, nv)
        params, opt_state, _ = _train_step(
            params, opt_state, kn, e_q, embeds_d[idx], labels_d[idx],
            cfg=cfg_m, opt_cfg=opt_cfg, kind="mlp",
            phase2=t >= cfg.phase1_steps)
    return params


def mlp_classifier_scores(params, embeds) -> jnp.ndarray:
    h = jax.nn.gelu(embeds @ params["w1"] + params["b1"])
    h = jax.nn.gelu(h @ params["w2"] + params["b2"])
    return jax.nn.sigmoid((h @ params["w3"] + params["b3"])[:, 0])
