"""ScaleDocPipeline — per-query compatibility shim over ScaleDocEngine.

  pipeline = ScaleDocPipeline(embeddings, proxy_cfg, cascade_cfg)
  result = pipeline.query(e_q, oracle, accuracy_target=0.9)

The original pipeline re-ran the full online phase from scratch per
query. It is now a thin wrapper over the persistent engine
(repro.engine.ScaleDocEngine), which adds a DocumentStore, a composable
Predicate algebra, cross-query oracle/proxy caches and pluggable cascade
strategies — new code should target the engine directly:

  engine = ScaleDocEngine(InMemoryStore(embeddings), proxy_cfg, cascade_cfg)
  res = engine.filter(SemanticPredicate(e_q1, o1) & ~SemanticPredicate(e_q2, o2),
                      accuracy_target=0.9)
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.config.base import CascadeConfig, ProxyConfig
from repro.core.cascade import CascadeResult


@dataclasses.dataclass
class QueryStats:
    cascade: CascadeResult
    oracle_calls_total: int
    oracle_calls_train: int
    proxy_flops: float
    oracle_flops: float
    total_flops: float
    wall_seconds: float
    scores: np.ndarray
    # degraded-mode accounting (engine degrade= policy under an oracle
    # outage): flagged so a consumer can tell contract-backed decisions
    # from best-effort ones
    degraded: bool = False
    degrade_mode: Optional[str] = None
    unresolved_docs: int = 0
    fallback_docs: int = 0
    est_accuracy_debit: float = 0.0


class ScaleDocPipeline:
    """Compatibility shim — NOT the primary API.

    Constructs a private ScaleDocEngine per instance and forwards
    ``query``; it keeps no predicate algebra, no pluggable strategies,
    and shares no caches across instances. New code should construct
    repro.engine.ScaleDocEngine directly (see docs/engine.md).
    """

    def __init__(self, embeds: np.ndarray, proxy_cfg: ProxyConfig,
                 cascade_cfg: CascadeConfig, use_kernel: bool = False):
        from repro.engine import ScaleDocEngine
        self.embeds = np.asarray(embeds, np.float32)
        self._engine = ScaleDocEngine(self.embeds, proxy_cfg, cascade_cfg,
                                      use_kernel=use_kernel)
        self.proxy_cfg = self._engine.proxy_cfg
        self.cascade_cfg = cascade_cfg
        self.use_kernel = use_kernel

    def query(self, e_q: np.ndarray, oracle, *,
              accuracy_target: Optional[float] = None,
              ground_truth: Optional[np.ndarray] = None,
              seed: int = 0) -> QueryStats:
        return self._engine.query(e_q, oracle,
                                  accuracy_target=accuracy_target,
                                  ground_truth=ground_truth, seed=seed)
