"""ScaleDocPipeline — the public API (deliverable a).

  pipeline = ScaleDocPipeline(embeddings, proxy_cfg, cascade_cfg)
  result = pipeline.query(e_q, oracle, accuracy_target=0.9)

Orchestrates the full online phase for one ad-hoc semantic predicate:
  1. sample + oracle-label a training subset (train_fraction)
  2. two-phase contrastive proxy training (repro.core.trainer)
  3. full-collection scoring (repro.core.scoring / Pallas kernels)
  4. adaptive cascade (repro.core.cascade)
and reports end-to-end cost accounting (oracle calls, FLOPs).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, Optional

import jax
import numpy as np

from repro.config.base import CascadeConfig, ProxyConfig, replace
from repro.core import oracle as oracle_mod
from repro.core.cascade import CascadeResult, run_cascade
from repro.core.scoring import score_collection
from repro.core.trainer import train_proxy


@dataclasses.dataclass
class QueryStats:
    cascade: CascadeResult
    oracle_calls_total: int
    oracle_calls_train: int
    proxy_flops: float
    oracle_flops: float
    total_flops: float
    wall_seconds: float
    scores: np.ndarray


class ScaleDocPipeline:
    def __init__(self, embeds: np.ndarray, proxy_cfg: ProxyConfig,
                 cascade_cfg: CascadeConfig, use_kernel: bool = False):
        self.embeds = np.asarray(embeds, np.float32)
        self.proxy_cfg = replace(proxy_cfg, embed_dim=self.embeds.shape[1])
        self.cascade_cfg = cascade_cfg
        self.use_kernel = use_kernel

    def query(self, e_q: np.ndarray, oracle, *,
              accuracy_target: Optional[float] = None,
              ground_truth: Optional[np.ndarray] = None,
              seed: int = 0) -> QueryStats:
        t0 = time.time()
        ccfg = self.cascade_cfg
        if accuracy_target is not None:
            ccfg = replace(ccfg, accuracy_target=accuracy_target)
        n = len(self.embeds)
        rng = np.random.default_rng(seed)
        from repro.core.oracle import CachedOracle
        oracle = CachedOracle(oracle)   # never pay twice for one label

        # 1. training sample + oracle labels
        calls0 = oracle.calls
        n_train = max(int(self.proxy_cfg.train_fraction * n), 16)
        train_idx = rng.choice(n, size=n_train, replace=False)
        train_labels = oracle.label(train_idx)
        train_calls = oracle.calls - calls0

        # 2. proxy training (two-phase contrastive)
        res = train_proxy(jax.random.PRNGKey(seed), e_q,
                          self.embeds[train_idx], train_labels,
                          self.proxy_cfg)

        # 3. full-collection scoring
        scores = score_collection(res.params, e_q, self.embeds,
                                  use_kernel=self.use_kernel)

        # 4. adaptive cascade
        cascade = run_cascade(scores, oracle, ccfg,
                              ground_truth=ground_truth, rng=rng)

        total_calls = oracle.calls - calls0
        proxy_flops = n * oracle_mod.OUR_PROXY_FLOPS_PER_DOC
        oracle_flops = total_calls * getattr(
            oracle, "flops_per_doc", oracle_mod.ORACLE_FLOPS_PER_DOC)
        return QueryStats(
            cascade=cascade,
            oracle_calls_total=total_calls,
            oracle_calls_train=train_calls,
            proxy_flops=proxy_flops,
            oracle_flops=oracle_flops,
            total_flops=proxy_flops + oracle_flops,
            wall_seconds=time.time() - t0,
            scores=scores,
        )
