# ScaleDoc's primary contribution: query-aware contrastive proxy training
# (§3) + adaptive cascade with calibrated thresholds (§4). These pieces
# are composed by repro.engine.ScaleDocEngine (the primary API);
# ScaleDocPipeline remains only as a per-query compatibility shim over
# it.
from repro.core.cascade import (  # noqa: F401
    CascadeResult,
    f1_score,
    naive_cascade,
    probe_cascade,
    run_cascade,
    supg_cascade,
)
from repro.core.encoder import (  # noqa: F401
    decision_scores,
    encoder_apply,
    encoder_init,
    projector_apply,
)
from repro.core.oracle import LMOracle, SimulatedOracle  # noqa: F401
from repro.core.pipeline import QueryStats, ScaleDocPipeline  # noqa: F401
from repro.core.trainer import (  # noqa: F401
    train_proxy,
    train_proxy_multi,
    train_proxy_variant,
    unstack_params,
)
