"""End-to-end adaptive cascade (paper §4): calibrate -> select thresholds
-> filter -> oracle the ambiguous band.

The cascade consumes decision scores from *any* proxy (our trained
encoder, an MLP classifier, raw embedding matching, or an LLM's logprobs)
— that pluggability is what the paper's §6.5 cascade ablations rely on.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from repro.config.base import CascadeConfig
from repro.core import calibration as calib_mod
from repro.core import thresholds as thr_mod
from repro.core.guarantees import accuracy_margin_for_selection, check_guarantee


@dataclasses.dataclass
class CascadeResult:
    labels: np.ndarray          # final binary decisions for all docs
    l: float
    r: float
    unfiltered_rate: float      # fraction sent to the oracle (online phase)
    oracle_calls_online: int    # oracle calls on the ambiguous band
    oracle_calls_calib: int     # oracle calls for calibration labels
    est_accuracy: float
    achieved_f1: Optional[float] = None
    achieved_exact: Optional[float] = None
    data_reduction: float = 0.0  # 1 - (all oracle calls) / N
    certified: Optional[bool] = None


@dataclasses.dataclass
class ThresholdSpec:
    """The calibration half of a threshold cascade: everything needed to
    decide any document later — thresholds, the labeled calibration
    sample (whose purchased labels the band resolution reuses), and the
    selection's quality estimates. Splitting this out of ``run_cascade``
    lets the engine calibrate a leaf once over the full collection and
    resolve only the ambiguous-band documents each query actually
    needs (repro.engine.optimizer shares the spec across sessions)."""
    l: float
    r: float
    sample_idx: np.ndarray
    sample_labels: np.ndarray
    est_accuracy: float
    oracle_calls_calib: int
    certified: Optional[bool] = None


def f1_score(pred: np.ndarray, truth: np.ndarray) -> float:
    pred = pred.astype(bool)
    truth = truth.astype(bool)
    tp = int(np.sum(pred & truth))
    fp = int(np.sum(pred & ~truth))
    fn = int(np.sum(~pred & truth))
    denom = 2 * tp + fp + fn
    return 2 * tp / denom if denom else 1.0


def resolve_ambiguous_band(scores: np.ndarray, l: float, r: float, oracle,
                           sample_idx, sample_labels
                           ) -> tuple:
    """Final labeling shared by every threshold-based strategy: auto-label
    outside (l, r), oracle the ambiguous band, reusing labels already
    purchased for the calibration/training sample.

    Returns (labels, ambiguous_mask, online_calls).
    """
    n = len(scores)
    auto_pos = scores > r
    ambiguous = ~(auto_pos | (scores < l))
    labels = np.zeros(n, bool)
    labels[auto_pos] = True
    known = {int(i): bool(lbl) for i, lbl in zip(sample_idx, sample_labels)}
    amb_idx = np.nonzero(ambiguous)[0]
    need = np.array([i for i in amb_idx if int(i) not in known],
                    dtype=np.int64)
    if len(need):
        labels[need] = oracle.label(need)
    for i in amb_idx:
        if int(i) in known:
            labels[i] = known[int(i)]
    return labels, ambiguous, len(need)


def calibrate_thresholds(scores: np.ndarray, oracle, cfg: CascadeConfig,
                         rng: Optional[np.random.Generator] = None
                         ) -> ThresholdSpec:
    """Calibrate + select thresholds over the full score vector — the
    oracle-sampling half of ``run_cascade``, with the band resolution
    left to the caller. Consumes ``rng`` in exactly the order
    ``run_cascade`` does, so composing it with
    ``resolve_ambiguous_band`` reproduces ``run_cascade`` bitwise."""
    rng = rng or np.random.default_rng(cfg.seed)
    calls_before = oracle.calls
    calib = calib_mod.calibrate(scores, oracle.label, cfg, rng)
    calib_calls = oracle.calls - calls_before

    mode = cfg.margin_mode
    if mode == "bootstrap":
        sel = thr_mod.select_thresholds_certified(
            calib, cfg.accuracy_target, metric=cfg.metric,
            n_boot=cfg.boot_samples, conf=cfg.boot_conf, rng=rng)
    else:
        margin = 0.0
        if mode == "bernstein":
            margin = accuracy_margin_for_selection(
                scores[calib.sample_idx], calib.sample_labels,
                cfg.accuracy_target, cfg.delta)
        sel = thr_mod.select_thresholds(calib, cfg.accuracy_target,
                                        metric=cfg.metric, margin=margin)

    guarantee = check_guarantee(scores[calib.sample_idx],
                                calib.sample_labels, sel.l, sel.r,
                                cfg.accuracy_target, cfg.delta)
    return ThresholdSpec(
        l=sel.l, r=sel.r, sample_idx=calib.sample_idx,
        sample_labels=calib.sample_labels, est_accuracy=sel.est_accuracy,
        oracle_calls_calib=calib_calls, certified=guarantee.certified)


def run_cascade(scores: np.ndarray, oracle, cfg: CascadeConfig,
                ground_truth: Optional[np.ndarray] = None,
                rng: Optional[np.random.Generator] = None) -> CascadeResult:
    """scores: (N,) proxy decision scores in [0, 1]; ``oracle.label(idx)``
    returns binary labels (and counts its own invocations)."""
    n = len(scores)
    spec = calibrate_thresholds(scores, oracle, cfg, rng)

    labels, ambiguous, online_calls = resolve_ambiguous_band(
        scores, spec.l, spec.r, oracle, spec.sample_idx,
        spec.sample_labels)

    result = CascadeResult(
        labels=labels, l=spec.l, r=spec.r,
        unfiltered_rate=float(ambiguous.mean()),
        oracle_calls_online=online_calls,
        oracle_calls_calib=spec.oracle_calls_calib,
        est_accuracy=spec.est_accuracy,
        data_reduction=1.0 - (online_calls + spec.oracle_calls_calib)
        / max(n, 1),
        certified=spec.certified,
    )
    if ground_truth is not None:
        truth = np.asarray(ground_truth).astype(bool)
        result.achieved_f1 = f1_score(labels, truth)
        result.achieved_exact = float(np.mean(labels == truth))
    return result


# -- baseline cascade strategies for §6.5 ------------------------------------

def naive_thresholds(scores: np.ndarray, oracle, cfg: CascadeConfig,
                     rng: Optional[np.random.Generator] = None
                     ) -> ThresholdSpec:
    """Calibration half of ``naive_cascade`` (raw empirical densities)."""
    rng = rng or np.random.default_rng(cfg.seed)
    n = len(scores)
    idx = rng.choice(n, size=max(int(cfg.calib_fraction * n), 8),
                     replace=False)
    labels_s = oracle.label(idx).astype(bool)
    calib_calls = len(idx)
    edges = calib_mod.discretize(cfg.num_bins)
    pdf_p = calib_mod.naive_density(scores[idx][labels_s], edges)
    pdf_n = calib_mod.naive_density(scores[idx][~labels_s], edges)
    calib = calib_mod.Calibration(pdf_pos=pdf_p, pdf_neg=pdf_n,
                                  prior_pos=float(labels_s.mean()),
                                  edges=edges, sample_idx=idx,
                                  sample_labels=labels_s)
    sel = thr_mod.select_thresholds(calib, cfg.accuracy_target,
                                    metric=cfg.metric)
    return ThresholdSpec(l=sel.l, r=sel.r, sample_idx=idx,
                         sample_labels=labels_s,
                         est_accuracy=sel.est_accuracy,
                         oracle_calls_calib=calib_calls)


def naive_cascade(scores: np.ndarray, oracle, cfg: CascadeConfig,
                  ground_truth=None) -> CascadeResult:
    """'Naive': thresholds straight from the raw sampled empirical
    distributions (no jitter / smoothing / stratification)."""
    spec = naive_thresholds(scores, oracle, cfg)
    return _finish(scores, oracle, spec, spec.oracle_calls_calib,
                   spec.sample_idx, spec.sample_labels, ground_truth)


def probe_cascade(scores: np.ndarray, oracle, cfg: CascadeConfig,
                  ground_truth=None, budget_frac: float = 0.5
                  ) -> CascadeResult:
    """'Probe-based calibration' (§6.5): iteratively oracle the most
    ambiguous documents (closest to 0.5) until the estimated accuracy of
    filtering the remainder meets the target."""
    n = len(scores)
    order = np.argsort(np.abs(scores - 0.5))
    labels = scores > 0.5
    step = max(n // 50, 8)
    probed = np.zeros(n, bool)
    spent = 0
    est = 0.0
    for k in range(step, int(budget_frac * n) + step, step):
        batch = order[spent:k]
        if not len(batch):
            break
        labels[batch] = oracle.label(batch)
        probed[batch] = True
        spent = k
        if spent >= n:
            break
        # the just-probed batch sits at the decision frontier, so the
        # proxy's agreement with the oracle there lower-bounds its
        # accuracy on the (easier) unprobed remainder
        proxy_right = np.mean((scores[batch] > 0.5) == labels[batch])
        est = proxy_right
        if proxy_right >= cfg.accuracy_target:
            break
    result = CascadeResult(
        labels=labels, l=0.0, r=1.0,
        unfiltered_rate=float(probed.mean()),
        oracle_calls_online=int(probed.sum()), oracle_calls_calib=0,
        est_accuracy=float(est),
        data_reduction=1.0 - probed.mean())
    if ground_truth is not None:
        truth = np.asarray(ground_truth).astype(bool)
        result.achieved_f1 = f1_score(labels, truth)
        result.achieved_exact = float(np.mean(labels == truth))
    return result


def supg_thresholds(scores: np.ndarray, oracle, cfg: CascadeConfig,
                    rng: Optional[np.random.Generator] = None
                    ) -> ThresholdSpec:
    """Calibration half of ``supg_cascade`` (importance-weighted CDF)."""
    rng = rng or np.random.default_rng(cfg.seed)
    n = len(scores)
    m = max(int(cfg.calib_fraction * n), 8)
    w = np.sqrt(np.clip(scores, 1e-3, None))
    p = w / w.sum()
    idx = rng.choice(n, size=m, replace=False, p=p)
    labels_s = oracle.label(idx).astype(bool)
    weights = 1.0 / (p[idx] * n)
    edges = calib_mod.discretize(cfg.num_bins)
    pdf_p = calib_mod.importance_density(scores[idx][labels_s],
                                         weights[labels_s], edges)
    pdf_n = calib_mod.importance_density(scores[idx][~labels_s],
                                         weights[~labels_s], edges)
    wsum = weights.sum()
    prior = float(weights[labels_s].sum() / wsum) if wsum > 0 else 0.5
    calib = calib_mod.Calibration(pdf_pos=pdf_p, pdf_neg=pdf_n,
                                  prior_pos=prior, edges=edges,
                                  sample_idx=idx, sample_labels=labels_s)
    sel = thr_mod.select_thresholds(calib, cfg.accuracy_target,
                                    metric=cfg.metric)
    return ThresholdSpec(l=sel.l, r=sel.r, sample_idx=idx,
                         sample_labels=labels_s,
                         est_accuracy=sel.est_accuracy,
                         oracle_calls_calib=m)


def supg_cascade(scores: np.ndarray, oracle, cfg: CascadeConfig,
                 ground_truth=None) -> CascadeResult:
    """SUPG-style (importance-sampled) threshold selection [Kang'20],
    approximated: importance sample ∝ sqrt(score) for recall-target-like
    behaviour, then select thresholds on the weighted empirical CDF."""
    spec = supg_thresholds(scores, oracle, cfg)
    return _finish(scores, oracle, spec, spec.oracle_calls_calib,
                   spec.sample_idx, spec.sample_labels, ground_truth)


def _finish(scores, oracle, sel, calib_calls, sample_idx, sample_labels,
            ground_truth) -> CascadeResult:
    n = len(scores)
    labels, ambiguous, online_calls = resolve_ambiguous_band(
        scores, sel.l, sel.r, oracle, sample_idx, sample_labels)
    result = CascadeResult(
        labels=labels, l=sel.l, r=sel.r,
        unfiltered_rate=float(ambiguous.mean()),
        oracle_calls_online=online_calls, oracle_calls_calib=calib_calls,
        est_accuracy=sel.est_accuracy,
        data_reduction=1.0 - (online_calls + calib_calls) / max(n, 1))
    if ground_truth is not None:
        truth = np.asarray(ground_truth).astype(bool)
        result.achieved_f1 = f1_score(labels, truth)
        result.achieved_exact = float(np.mean(labels == truth))
    return result
