"""Full-collection proxy scoring — the online hot loop.

For every ad-hoc query, ScaleDoc scores *all N* document embeddings with
the freshly trained proxy: z_d = MLP(e_d); s = (1+cos(z_q, z_d))/2.

On TPU this dispatches to the fused Pallas kernels
(repro.kernels.mlp_encoder + repro.kernels.fused_scoring) so hidden
activations never leave VMEM; the pure-jnp path below is the oracle and
the CPU fallback. Batched in chunks so the working set stays bounded for
collections of millions of documents.

Two entry points:

  * ``score_collection``       — one (params, e_q) over the collection;
  * ``score_collection_multi`` — many predicates in ONE pass over the
    collection: each chunk is read from the store once, encoded once per
    distinct proxy, and all pending query vectors sharing that proxy are
    scored with a single stacked z_q matmul (with the raw-embedding
    proxy the whole batch collapses to one matmul per chunk).

These are the *reference* scoring paths. The engine's hot path is
repro.engine.executor.ScoringExecutor, which adds chunk prefetching
(double buffering), mesh sharding, and the fused multi-query Pallas
kernel — its default mode runs the exact per-chunk jitted programs
defined here, so both paths produce bit-identical scores.

``embeds`` may be a raw (N, D) array or anything exposing
``iter_chunks(chunk)`` (see repro.engine.store.DocumentStore), so
scoring streams from disk for collections that exceed RAM.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.encoder import encoder_apply, l2_normalize


def _iter_chunks(embeds, chunk: int):
    if hasattr(embeds, "iter_chunks"):
        yield from embeds.iter_chunks(chunk)
        return
    n = embeds.shape[0]
    for start in range(0, n, chunk):
        yield start, embeds[start:start + chunk]


def _num_docs(embeds) -> int:
    return len(embeds) if hasattr(embeds, "iter_chunks") else embeds.shape[0]


def score_collection(params: Dict, e_q: jnp.ndarray, embeds,
                     chunk: int = 8192, use_kernel: bool = False
                     ) -> np.ndarray:
    """Scores for all docs. embeds: (N, D) array or DocumentStore ->
    (N,) float32 in [0, 1]."""
    if use_kernel and not hasattr(embeds, "iter_chunks"):
        from repro.kernels.fused_scoring import ops as scoring_ops
        return np.asarray(scoring_ops.score_collection(params, e_q, embeds))
    z_q = l2_normalize(encoder_apply(params, e_q))
    outs = []
    for _, block in _iter_chunks(embeds, chunk):
        outs.append(np.asarray(_single_chunk_scores(params, block, z_q)))
    return np.concatenate(outs).astype(np.float32)


def _single_chunk_scores_impl(params, block, z_q):
    """block: (B, D); z_q: (latent,) normalized query latent.

    Module-level (rather than a closure) so the streaming executor
    (repro.engine.executor) runs the *same* jitted program and stays
    bit-identical to this reference path; the unjitted impl is what the
    executor wraps in shard_map for the multi-device path.
    """
    z = encoder_apply(params, block)
    cos = l2_normalize(z) @ z_q
    return (1.0 + cos) * 0.5


_single_chunk_scores = jax.jit(_single_chunk_scores_impl)


def _proxy_chunk_scores_impl(params, block, zq_t):
    """block: (B, D); zq_t: (latent, Q) of normalized query latents."""
    z = l2_normalize(encoder_apply(params, block))
    return (1.0 + z @ zq_t) * 0.5


def _raw_chunk_scores_impl(block, zq_t):
    return (1.0 + l2_normalize(block) @ zq_t) * 0.5


_proxy_chunk_scores = jax.jit(_proxy_chunk_scores_impl)
_raw_chunk_scores = jax.jit(_raw_chunk_scores_impl)


def group_jobs(jobs: Sequence[Tuple[Optional[Dict], np.ndarray]]
               ) -> Tuple[List[Tuple[Optional[Dict], List[int]]],
                          List[jnp.ndarray]]:
    """Group (params, e_q) jobs by proxy identity for batched scoring.

    Returns ``(groups, zq_stacks)``: per distinct params object (or
    None = raw cosine) the job-column indices it covers, plus the
    matching (Q_g, latent) stack of normalized query latents. Shared by
    ``score_collection_multi`` and the streaming executor so grouping
    key and column order cannot drift between the two paths.
    """
    groups: List[Tuple[Optional[Dict], List[int]]] = []
    by_id: Dict[int, int] = {}
    for j, (params, _) in enumerate(jobs):
        key = -1 if params is None else id(params)
        if key not in by_id:
            by_id[key] = len(groups)
            groups.append((params, []))
        groups[by_id[key]][1].append(j)

    zq_stacks = []
    for params, cols in groups:
        e_qs = jnp.stack([jnp.asarray(jobs[j][1]) for j in cols])
        if params is None:
            zq_stacks.append(l2_normalize(e_qs))
        else:
            zq_stacks.append(l2_normalize(encoder_apply(params, e_qs)))
    return groups, zq_stacks


def score_collection_multi(jobs: Sequence[Tuple[Optional[Dict], np.ndarray]],
                           embeds, chunk: int = 8192) -> np.ndarray:
    """Score many predicates in one streaming pass over the collection.

    jobs: sequence of (params, e_q); ``params=None`` means raw-embedding
    cosine (no proxy). Returns (N, len(jobs)) float32 scores in [0, 1],
    columns in job order. Jobs sharing the same params object are scored
    with one encoder pass and one stacked matmul per chunk.
    """
    if not jobs:
        return np.zeros((_num_docs(embeds), 0), np.float32)

    groups, zq_stacks = group_jobs(jobs)
    zq_ts = [zq.T for zq in zq_stacks]

    n = _num_docs(embeds)
    out = np.empty((n, len(jobs)), np.float32)
    for start, block in _iter_chunks(embeds, chunk):
        block = jnp.asarray(block)
        for (params, cols), zq_t in zip(groups, zq_ts):
            if params is None:
                s = _raw_chunk_scores(block, zq_t)
            else:
                s = _proxy_chunk_scores(params, block, zq_t)
            out[start:start + block.shape[0], np.asarray(cols)] = \
                np.asarray(s, np.float32)
    return out


def direct_embedding_scores(e_q: jnp.ndarray, embeds: jnp.ndarray
                            ) -> np.ndarray:
    """Baseline: off-the-shelf embedding matching (paper §6.4 / Table 3) —
    cosine between raw embeddings, no trained proxy."""
    cos = l2_normalize(embeds) @ l2_normalize(e_q)
    return np.asarray((1.0 + cos) * 0.5, dtype=np.float32)
