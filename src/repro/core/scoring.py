"""Full-collection proxy scoring — the online hot loop.

For every ad-hoc query, ScaleDoc scores *all N* document embeddings with
the freshly trained proxy: z_d = MLP(e_d); s = (1+cos(z_q, z_d))/2.

On TPU this dispatches to the fused Pallas kernels
(repro.kernels.mlp_encoder + repro.kernels.fused_scoring) so hidden
activations never leave VMEM; the pure-jnp path below is the oracle and
the CPU fallback. Batched in chunks so the working set stays bounded for
collections of millions of documents.
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.encoder import encoder_apply, l2_normalize


def score_collection(params: Dict, e_q: jnp.ndarray, embeds: jnp.ndarray,
                     chunk: int = 8192, use_kernel: bool = False
                     ) -> np.ndarray:
    """Scores for all docs. embeds: (N, D) -> (N,) float32 in [0, 1]."""
    if use_kernel:
        from repro.kernels.fused_scoring import ops as scoring_ops
        return np.asarray(scoring_ops.score_collection(params, e_q, embeds))
    z_q = l2_normalize(encoder_apply(params, e_q))

    @jax.jit
    def score_chunk(chunk_embeds):
        z = encoder_apply(params, chunk_embeds)
        cos = l2_normalize(z) @ z_q
        return (1.0 + cos) * 0.5

    n = embeds.shape[0]
    outs = []
    for start in range(0, n, chunk):
        outs.append(np.asarray(score_chunk(embeds[start:start + chunk])))
    return np.concatenate(outs).astype(np.float32)


def direct_embedding_scores(e_q: jnp.ndarray, embeds: jnp.ndarray
                            ) -> np.ndarray:
    """Baseline: off-the-shelf embedding matching (paper §6.4 / Table 3) —
    cosine between raw embeddings, no trained proxy."""
    cos = l2_normalize(embeds) @ l2_normalize(e_q)
    return np.asarray((1.0 + cos) * 0.5, dtype=np.float32)
