"""Theoretical accuracy guarantee (paper §4.4, Proposition 1).

Bernstein concentration of the weighted error functional
  Z_i = (1 - a/2) 1[pos & s_i < l] + (a/2) 1[neg & s_i > r]
gives a safety margin eps such that, if the *sample* satisfies
  T_S'(l, r) <= (1 - a) F+_S' - eps,
then the *population* accuracy exceeds alpha w.p. >= 1 - delta.

  eps = (sqrt(var_Z) + (1-a) sqrt(var_P)) * sqrt(4 ln(4/delta) / (pN))
        + (8 - 6a) ln(4/delta) / (3 pN)
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np


@dataclasses.dataclass
class GuaranteeReport:
    epsilon: float
    t_sample: float      # T_S'(l, r)
    rhs: float           # (1 - alpha) F+_S'
    certified: bool      # t_sample <= rhs - epsilon


def _z_values(scores: np.ndarray, labels: np.ndarray, l: float, r: float,
              alpha: float) -> np.ndarray:
    pos = labels.astype(bool)
    z = np.zeros(len(scores))
    z += (1 - alpha / 2) * (pos & (scores < l))
    z += (alpha / 2) * (~pos & (scores > r))
    return z


def bernstein_epsilon(var_z: float, var_p: float, alpha: float,
                      delta: float, n_sample: int) -> float:
    n = max(n_sample, 1)
    log_term = np.log(4.0 / delta)
    eps = ((np.sqrt(max(var_z, 0.0)) + (1 - alpha) * np.sqrt(max(var_p, 0.0)))
           * np.sqrt(4.0 * log_term / n)
           + (8 - 6 * alpha) * log_term / (3.0 * n))
    return float(eps)


def check_guarantee(sample_scores: np.ndarray, sample_labels: np.ndarray,
                    l: float, r: float, alpha: float,
                    delta: float) -> GuaranteeReport:
    """Proposition 1's sample condition for thresholds (l, r)."""
    n = len(sample_scores)
    labels = sample_labels.astype(bool)
    z = _z_values(sample_scores, labels, l, r, alpha)
    t_sample = float(z.mean()) if n else 0.0
    f_pos = float(labels.mean()) if n else 0.0
    var_z = float(z.var()) if n else 0.0
    var_p = float(labels.astype(float).var()) if n else 0.0
    eps = bernstein_epsilon(var_z, var_p, alpha, delta, n)
    rhs = (1 - alpha) * f_pos
    return GuaranteeReport(epsilon=eps, t_sample=t_sample, rhs=rhs,
                           certified=t_sample <= rhs - eps)


def accuracy_margin_for_selection(sample_scores: np.ndarray,
                                  sample_labels: np.ndarray,
                                  alpha: float, delta: float) -> float:
    """A conservative uplift on the selection target: pick thresholds
    against alpha' = alpha + margin so the certified condition holds with
    slack. Uses worst-case variances (bounded by Bernoulli 1/4 scaled)."""
    n = max(len(sample_scores), 1)
    var_p = float(sample_labels.astype(float).var()) if n else 0.25
    # var_z bounded by (1 - alpha/2)^2 / 4 in the worst case
    var_z = (1 - alpha / 2) ** 2 * 0.25
    eps = bernstein_epsilon(var_z, var_p, alpha, delta, n)
    # translate the T-functional margin into an accuracy-target uplift:
    # d(Acc)/d(T) ~ -2 near the operating point, so uplift ~ 2 eps,
    # clipped to keep the target < 1.
    return float(min(2.0 * eps, 0.5 * (1.0 - alpha)))
