"""Pure-jnp oracle: delegates to repro.core.losses (the training path)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import losses


def ref_losses(z_q, z_d, y, tau, lam):
    qsim = losses.qsim_loss(z_q, z_d, y, tau)
    supcon = losses.supcon_loss(z_d, y, tau)
    polar = losses.polar_loss(z_q, z_d, y, tau)
    return jnp.stack([qsim, supcon, polar,
                      lam * supcon + (1 - lam) * polar])


def ref_phase2(z_q, z_d, y, tau, lam):
    """The phase-2 objective alone (what the trainer differentiates);
    identical math to the training path in repro.core.losses."""
    return losses.phase2_loss(z_q, z_d, y, tau, lam)
