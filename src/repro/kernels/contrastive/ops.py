"""Dispatch wrappers: Pallas on TPU, jnp oracle elsewhere.

``losses`` is the evaluation/monitoring entry (all four loss values).
``phase2_loss`` is the *trainable* entry the scanned proxy trainer puts
on its hot path: on TPU (or anywhere under ``impl="interpret"``) the
forward value comes from the fused Pallas kernel via a ``custom_vjp``
whose backward replays the pure-jnp reference objective — numerically
the exact gradient of the reference loss, checked against the kernel
forward in interpret mode by tests/test_kernels.py. When the kernel is
not in play (the CPU default) it is plain autodiff of the reference.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.contrastive import ref
from repro.kernels.contrastive.contrastive import contrastive_losses


def _use_kernel(impl: str) -> bool:
    if impl == "ref":
        return False
    if impl in ("kernel", "interpret"):
        return True
    return jax.default_backend() == "tpu"


def losses(z_q, z_d, y, tau: float, lam: float, *, force_ref: bool = False,
           interpret: bool = False):
    on_tpu = jax.default_backend() == "tpu"
    if (on_tpu or interpret) and not force_ref:
        return contrastive_losses(z_q, z_d, y, tau, lam,
                                  interpret=interpret)
    return ref.ref_losses(z_q, z_d, y, tau, lam)


def phase2_loss(z_q, z_d, y, tau: float, lam: float, impl: str = "auto"):
    """lam * L_supcon + (1 - lam) * L_polar, differentiable w.r.t. the
    latents.

    ``impl``: "auto" (Pallas kernel on TPU, reference elsewhere),
    "kernel" (force Pallas, compiled), "interpret" (force Pallas in
    interpret mode — runs on any backend), or "ref" (pure jnp). The
    backward pass is always the reference VJP; swapping ``impl`` never
    changes gradients, only who computes the forward value.

    When ``impl`` resolves to the reference (the CPU default), this is
    plain autodiff of the reference objective — no custom_vjp wrapper,
    so forward residuals are shared with the backward as usual. The
    kernel path wraps the Pallas forward in a custom_vjp that saves only
    the inputs and rematerializes the reference forward inside the
    backward (the standard memory-lean pattern for opaque kernels: the
    batch is small, so recompute is cheaper than plumbing residuals out
    of the kernel).
    """
    if not _use_kernel(impl):
        return ref.ref_phase2(z_q, z_d, y, tau, lam)
    return _phase2_kernel(z_q, z_d, y, tau, lam, impl)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _phase2_kernel(z_q, z_d, y, tau, lam, impl):
    out, _ = _phase2_fwd(z_q, z_d, y, tau, lam, impl)
    return out


def _phase2_fwd(z_q, z_d, y, tau, lam, impl):
    val = contrastive_losses(z_q, z_d, y, tau, lam,
                             interpret=(impl == "interpret"))[3]
    return val, (z_q, z_d, y)


def _phase2_bwd(tau, lam, impl, res, g):
    z_q, z_d, y = res
    _, vjp = jax.vjp(
        lambda zq, zd: ref.ref_phase2(zq, zd, y, tau, lam), z_q, z_d)
    gq, gd = vjp(g)
    return gq, gd, jnp.zeros_like(y)


_phase2_kernel.defvjp(_phase2_fwd, _phase2_bwd)
