"""Dispatch wrapper: Pallas on TPU, jnp oracle elsewhere."""
from __future__ import annotations

import jax

from repro.kernels.contrastive import ref
from repro.kernels.contrastive.contrastive import contrastive_losses


def losses(z_q, z_d, y, tau: float, lam: float, *, force_ref: bool = False,
           interpret: bool = False):
    on_tpu = jax.default_backend() == "tpu"
    if (on_tpu or interpret) and not force_ref:
        return contrastive_losses(z_q, z_d, y, tau, lam,
                                  interpret=interpret)
    return ref.ref_losses(z_q, z_d, y, tau, lam)
