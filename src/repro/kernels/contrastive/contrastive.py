"""Pallas TPU kernel: fused contrastive objectives for one mini-batch.

Computes all three ScaleDoc losses (L_qsim / L_supcon / L_polar) from the
projected latents in one VMEM-resident pass: the (n, n) similarity matrix
is built once on the MXU and every masked logsumexp reduction happens
before anything is written back to HBM. Batches are small (n <= 512,
p <= 256), so a single program handles the batch:

  VMEM: zd (n, p) + sims (n, n) + masks ~= 512*256*4 + 512*512*4 < 2 MiB.

Output: (4,) f32 = [qsim, supcon, polar, phase2 = lam*supcon+(1-lam)*polar].

The kernel sits on the training hot path: ``ops.phase2_loss`` wraps it
in a custom_vjp (kernel forward on TPU, reference VJP backward), and the
scanned proxy trainer in repro.core.trainer differentiates through that
wrapper every phase-2 step. It doubles as the fast evaluation/monitoring
path via ``ops.losses``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _lse(vals, mask):
    masked = jnp.where(mask, vals, NEG)
    m = jnp.max(masked, axis=-1, keepdims=True)
    safe = jnp.where(m > NEG / 2, m, 0.0)
    return (jnp.log(jnp.sum(jnp.where(mask, jnp.exp(masked - safe), 0.0),
                            axis=-1)) + safe[..., 0])


def _contrastive_kernel(zq_ref, zd_ref, y_ref, scalars_ref, out_ref):
    tau = scalars_ref[0]
    lam = scalars_ref[1]
    zq = zq_ref[...]
    zd = zd_ref[...]
    y = y_ref[...]
    n = zd.shape[0]

    # L2 normalize in-register
    zqn = zq / jnp.sqrt(jnp.maximum(jnp.sum(zq * zq), 1e-16))
    zdn = zd / jnp.sqrt(jnp.maximum(jnp.sum(zd * zd, axis=-1,
                                            keepdims=True), 1e-16))
    pos = y > 0.5
    neg = ~pos
    any_pos = jnp.any(pos)
    any_neg = jnp.any(neg)

    # ---- qsim (per-positive InfoNCE, query anchor) ----
    sims_q = (zdn @ zqn) / tau                     # (n,)
    lse_all = _lse(sims_q[None, :], jnp.ones((1, n), bool))[0]
    per = -(sims_q - lse_all)
    qsim = jnp.where(any_pos,
                     jnp.sum(jnp.where(pos, per, 0.0))
                     / jnp.maximum(jnp.sum(pos), 1), 0.0)

    # ---- pairwise sims (MXU) ----
    sims = jnp.dot(zdn, zdn.T,
                   preferred_element_type=jnp.float32) / tau   # (n, n)
    ids = jax.lax.iota(jnp.int32, n)
    eye = ids[:, None] == ids[None, :]
    same = (pos[:, None] == pos[None, :])

    # ---- supcon ----
    u_mask = same & ~eye
    a_mask = ~eye
    u_count = jnp.sum(u_mask, axis=1)
    lse_u = _lse(sims, u_mask)
    lse_a = _lse(sims, a_mask)
    per_anchor = -(lse_u - lse_a) / jnp.maximum(u_count, 1)
    valid = u_count > 0
    supcon = (jnp.sum(jnp.where(valid, per_anchor, 0.0))
              / jnp.maximum(jnp.sum(valid), 1))

    # ---- polar (bellwether anchors) ----
    pos_scores = jnp.where(pos, sims_q, jnp.inf)
    neg_scores = jnp.where(neg, sims_q, -jnp.inf)
    i_pos = jnp.argmin(pos_scores)
    i_neg = jnp.argmax(neg_scores)
    sims_bp = sims[i_pos]                           # row against d_pos
    sims_bn = sims[i_neg]
    ones = jnp.ones((n,), bool)
    loss_p = -(_lse(sims_bp[None], pos[None])[0]
               - _lse(sims_bp[None], ones[None])[0])
    loss_n = -(_lse(sims_bn[None], neg[None])[0]
               - _lse(sims_bn[None], ones[None])[0])
    polar = (jnp.where(any_pos, loss_p, 0.0)
             + jnp.where(any_neg, loss_n, 0.0))

    out_ref[0] = qsim
    out_ref[1] = supcon
    out_ref[2] = polar
    out_ref[3] = lam * supcon + (1.0 - lam) * polar


@functools.partial(jax.jit, static_argnames=("interpret",))
def contrastive_losses(z_q: jnp.ndarray, z_d: jnp.ndarray, y: jnp.ndarray,
                       tau: float, lam: float, *,
                       interpret: bool = False) -> jnp.ndarray:
    """z_q: (p,); z_d: (n, p); y: (n,) float {0,1}.
    Returns (4,) f32 [qsim, supcon, polar, phase2]."""
    n, p = z_d.shape
    scalars = jnp.asarray([tau, lam], jnp.float32)
    return pl.pallas_call(
        _contrastive_kernel,
        grid=(1,),
        in_specs=[
            pl.BlockSpec((p,), lambda i: (0,)),
            pl.BlockSpec((n, p), lambda i: (0, 0)),
            pl.BlockSpec((n,), lambda i: (0,)),
            pl.BlockSpec((2,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((4,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((4,), jnp.float32),
        interpret=interpret,
    )(z_q.astype(jnp.float32), z_d.astype(jnp.float32),
      y.astype(jnp.float32), scalars)
