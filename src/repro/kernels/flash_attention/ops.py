"""Dispatch wrapper: Pallas flash on TPU, XLA blocked elsewhere."""
from __future__ import annotations

import jax

from repro.models.attention import attention_blocked


def attention(q, k, v, *, scale, causal=True, window=0, q_offset=0,
              force_ref: bool = False, interpret: bool = False):
    on_tpu = jax.default_backend() == "tpu"
    if (on_tpu or interpret) and not force_ref:
        from repro.kernels.flash_attention.flash import flash_attention_fwd
        return flash_attention_fwd(q, k, v, scale=scale, causal=causal,
                                   window=window, q_offset=q_offset,
                                   interpret=interpret)
    return attention_blocked(q, k, v, scale, causal=causal, window=window,
                             q_offset=q_offset)
