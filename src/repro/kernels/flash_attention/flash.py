"""Pallas TPU kernel: blocked online-softmax attention (forward).

The offline representation phase runs prefill over every document — the
single largest FLOP consumer in ScaleDoc's pipeline — and pure-XLA blocked
attention spills every (q_block, kv_block) score tile to HBM (see the
roofline baselines in EXPERIMENTS.md). This kernel keeps the running
max/sum rescale and the score tile in VMEM, streaming K/V blocks HBM→VMEM.

TPU adaptation notes (vs the CUDA FlashAttention it reproduces):
  * tiles are (Q_BLOCK, KV_BLOCK) = (128, 128) multiples of the MXU's
    128x128 systolic contraction and the (8, 128) VPU lane layout;
  * no warp shuffles: the online-softmax running stats (m, l) live in
    VREGs across the fori_loop over KV blocks;
  * layout is (b*h, s, hd) so each program owns one (batch, head) row
    of query blocks — grid (bh, nq).

Forward only (decode/prefill serving); training uses the custom-VJP
recompute path in repro.models.attention (same math, same oracle).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30
Q_BLOCK = 128
KV_BLOCK = 128


def _flash_kernel(q_ref, k_ref, v_ref, out_ref, *, scale, causal, window,
                  q_offset, kv_len, kv_block):
    # q_ref: (Q_BLOCK, hd); k_ref/v_ref: (kv_len_padded, hd)
    qi = pl.program_id(1)
    q = q_ref[...].astype(jnp.float32) * scale
    qb, hd = q.shape
    nk = k_ref.shape[0] // kv_block

    def body(j, carry):
        m, l, acc = carry
        k = k_ref[pl.dslice(j * kv_block, kv_block), :].astype(jnp.float32)
        v = v_ref[pl.dslice(j * kv_block, kv_block), :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        qpos = q_offset + qi * qb + jax.lax.iota(jnp.int32, qb)
        kpos = j * kv_block + jax.lax.iota(jnp.int32, kv_block)
        valid = kpos[None, :] < kv_len
        if causal:
            valid = valid & (kpos[None, :] <= qpos[:, None])
        if window > 0:
            valid = valid & (kpos[None, :] > qpos[:, None] - window)
        s = jnp.where(valid, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    m0 = jnp.full((qb,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((qb,), jnp.float32)
    a0 = jnp.zeros((qb, hd), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, nk, body, (m0, l0, a0))
    out_ref[...] = (acc / jnp.maximum(l[:, None], 1e-30)).astype(
        out_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "scale", "causal", "window", "q_offset", "q_block", "kv_block",
    "interpret"))
def flash_attention_fwd(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                        scale: float, causal: bool = True, window: int = 0,
                        q_offset: int = 0, q_block: int = Q_BLOCK,
                        kv_block: int = KV_BLOCK,
                        interpret: bool = False) -> jnp.ndarray:
    """q: (b, sq, h, hd); k, v: (b, skv, h, hd) -> (b, sq, h, hd)."""
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    q_block = min(q_block, sq)
    kv_block = min(kv_block, skv)
    pq = (-sq) % q_block
    pk = (-skv) % kv_block

    def to_bh(x, pad):
        if pad:
            x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return x.transpose(0, 2, 1, 3).reshape(b * h, x.shape[1], hd)

    qb = to_bh(q, pq)
    kb = to_bh(k, pk)
    vb = to_bh(v, pk)
    nq = (sq + pq) // q_block

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               window=window, q_offset=q_offset,
                               kv_len=skv, kv_block=kv_block)
    out = pl.pallas_call(
        kernel,
        grid=(b * h, nq),
        in_specs=[
            pl.BlockSpec((None, q_block, hd), lambda bh, i: (bh, i, 0)),
            pl.BlockSpec((None, skv + pk, hd), lambda bh, i: (bh, 0, 0)),
            pl.BlockSpec((None, skv + pk, hd), lambda bh, i: (bh, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, q_block, hd), lambda bh, i: (bh, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq + pq, hd), q.dtype),
        interpret=interpret,
    )(qb, kb, vb)
    out = out.reshape(b, h, sq + pq, hd)[:, :, :sq]
    return out.transpose(0, 2, 1, 3)
