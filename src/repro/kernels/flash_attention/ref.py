"""Pure-jnp oracle for the flash attention kernel: masked einsum softmax."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.attention import attention_einsum


def ref_attention(q, k, v, *, scale, causal=True, window=0, q_offset=0):
    sq, skv = q.shape[1], k.shape[1]
    iq = jnp.arange(sq) + q_offset
    ik = jnp.arange(skv)
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask = mask & (ik[None, :] <= iq[:, None])
    if window > 0:
        mask = mask & (ik[None, :] > iq[:, None] - window)
    return attention_einsum(q, k, v, mask, scale)
