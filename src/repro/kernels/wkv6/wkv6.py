"""Pallas TPU kernel: WKV6 (RWKV6 "Finch") intra-chunk recurrence.

The community runs RWKV6 through a sequential CUDA kernel (one thread
block per (batch, head), stepping token by token). That shape is wrong
for a TPU; we instead use the *chunked* linear-attention formulation —
but its intra-chunk matrix

    A[t, j] = sum_k r[t,k] * exp(cum[t-1,k] - cum[j,k]) * k[j,k],  j < t

is numerically unfactorable in f32 (exp(-cum_j) overflows under strong
decay), so the pure-XLA path must clamp the per-step decay. The kernel
removes the compromise: the (Q, Q, KS) pairwise-decay slab lives in VMEM
and is contracted slab-by-slab over the head dim — every exponent is of
the *difference* (<= 0: no overflow), nothing spills to HBM.

Per program (grid = (b, nc, h)): tiles r, k, v, cum, lw of (Q, K), bonus
u (K,). Emits everything the (cheap) inter-chunk scan outside needs:

    y_intra (Q, K)  = A @ v + (r.u.k) v        intra-chunk output
    s_inj   (K, K)  = (k * exp(cum_Q - cum))^T @ v   state injection
    a_end   (K,)    = exp(cum_Q)               chunk decay of the state
    r_dec   (Q, K)  = r * exp(cum_{t-1})       inter-chunk read weights

VMEM @ Q=128, K=64, slab=16: 5 tiles * 32 KiB + A 64 KiB + slab buffer
(128*128*16*4 = 1 MiB) ~= 1.3 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

K_SLAB = 16


def _wkv6_kernel(r_ref, k_ref, v_ref, cum_ref, lw_ref, u_ref,
                 y_ref, sinj_ref, aend_ref, rdec_ref, *, k_slab):
    r = r_ref[...].astype(jnp.float32)          # (Q, K)
    kk = k_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    cum = cum_ref[...].astype(jnp.float32)
    lw = lw_ref[...].astype(jnp.float32)
    u = u_ref[...].astype(jnp.float32)          # (K,)
    Q, K = r.shape
    cum_tm1 = cum - lw

    tri = (jax.lax.iota(jnp.int32, Q)[:, None]
           > jax.lax.iota(jnp.int32, Q)[None, :])   # strict lower

    def slab(i, A):
        sl = lambda x: jax.lax.dynamic_slice_in_dim(x, i * k_slab, k_slab,
                                                    axis=1)
        ct, cj, rs, ks = sl(cum_tm1), sl(cum), sl(r), sl(kk)
        seg = ct[:, None, :] - cj[None, :, :]      # (Q, Q, KS) <= 0 on tri
        dec = jnp.where(tri[:, :, None], jnp.exp(seg), 0.0)
        contrib = jnp.einsum("qs,qjs,js->qj", rs, dec, ks)
        return A + contrib

    A = jax.lax.fori_loop(0, K // k_slab, slab,
                          jnp.zeros((Q, Q), jnp.float32))
    diag = jnp.sum(r * u[None, :] * kk, axis=-1)     # (Q,)
    y = jnp.dot(A, v, preferred_element_type=jnp.float32) \
        + diag[:, None] * v
    dec_end = jnp.exp(cum[-1][None, :] - cum)        # (Q, K)
    s_inj = jnp.dot((kk * dec_end).T, v,
                    preferred_element_type=jnp.float32)   # (K, K)
    y_ref[...] = y
    sinj_ref[...] = s_inj
    aend_ref[...] = jnp.exp(cum[-1])
    rdec_ref[...] = r * jnp.exp(cum_tm1)


@functools.partial(jax.jit, static_argnames=("k_slab", "interpret"))
def wkv6_intra_chunk(r, k, v, cum, lw, u, *, k_slab: int = K_SLAB,
                     interpret: bool = False):
    """All inputs (b, nc, Q, H, K) f32 (cum = within-chunk cumsum of
    log-decay); u: (H, K). Returns (y_intra, s_inj, a_end, r_dec) with
    shapes ((b,nc,Q,H,K), (b,nc,H,K,K), (b,nc,H,K), (b,nc,Q,H,K))."""
    b, nc, Q, H, K = r.shape
    ks = min(k_slab, K)

    def to_grid(x):  # (b, nc, Q, H, K) -> (b*nc*H, Q, K)
        return (x.transpose(0, 1, 3, 2, 4)
                .reshape(b * nc * H, Q, K).astype(jnp.float32))

    rg, kg, vg, cg, lg = map(to_grid, (r, k, v, cum, lw))
    ug = jnp.broadcast_to(u[None, None], (b, nc, H, K)).reshape(
        b * nc * H, K).astype(jnp.float32)

    kernel = functools.partial(_wkv6_kernel, k_slab=ks)
    y, sinj, aend, rdec = pl.pallas_call(
        kernel,
        grid=(b * nc * H,),
        in_specs=[
            pl.BlockSpec((None, Q, K), lambda g: (g, 0, 0)),
            pl.BlockSpec((None, Q, K), lambda g: (g, 0, 0)),
            pl.BlockSpec((None, Q, K), lambda g: (g, 0, 0)),
            pl.BlockSpec((None, Q, K), lambda g: (g, 0, 0)),
            pl.BlockSpec((None, Q, K), lambda g: (g, 0, 0)),
            pl.BlockSpec((None, K), lambda g: (g, 0)),
        ],
        out_specs=[
            pl.BlockSpec((None, Q, K), lambda g: (g, 0, 0)),
            pl.BlockSpec((None, K, K), lambda g: (g, 0, 0)),
            pl.BlockSpec((None, K), lambda g: (g, 0)),
            pl.BlockSpec((None, Q, K), lambda g: (g, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b * nc * H, Q, K), jnp.float32),
            jax.ShapeDtypeStruct((b * nc * H, K, K), jnp.float32),
            jax.ShapeDtypeStruct((b * nc * H, K), jnp.float32),
            jax.ShapeDtypeStruct((b * nc * H, Q, K), jnp.float32),
        ],
        interpret=interpret,
    )(rg, kg, vg, cg, lg, ug)

    def back(x, extra):  # (b*nc*H, ...) -> (b, nc, ..., H, ...)
        return x.reshape((b, nc, H) + extra)

    y = back(y, (Q, K)).transpose(0, 1, 3, 2, 4)
    rdec = back(rdec, (Q, K)).transpose(0, 1, 3, 2, 4)
    sinj = back(sinj, (K, K))
    aend = back(aend, (K,))
    return y, sinj, aend, rdec


def wkv6_chunked(r, k, v, cum, lw, u, *, interpret: bool = False):
    """Full WKV6: Pallas intra-chunk + lax.scan inter-chunk combine.
    Inputs (b, nc, Q, H, K); returns y (b, nc, Q, H, K) f32."""
    y_intra, s_inj, a_end, r_dec = wkv6_intra_chunk(
        r, k, v, cum, lw, u, interpret=interpret)
    b, nc, Q, H, K = r.shape

    def body(S, inp):
        yc, sc, ac, rc = inp
        y_int = jnp.einsum("bqhk,bhkv->bqhv", rc, S)
        S_new = ac[..., None] * S + sc
        return S_new, yc + y_int

    S0 = jnp.zeros((b, H, K, K), jnp.float32)
    _, ys = jax.lax.scan(
        body, S0,
        (y_intra.transpose(1, 0, 2, 3, 4), s_inj.transpose(1, 0, 2, 3, 4),
         a_end.transpose(1, 0, 2, 3), r_dec.transpose(1, 0, 2, 3, 4)))
    return ys.transpose(1, 0, 2, 3, 4)
