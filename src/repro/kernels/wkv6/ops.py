"""Dispatch wrapper for WKV6: Pallas chunked kernel on TPU, exact
sequential reference elsewhere."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.wkv6 import ref
from repro.kernels.wkv6.wkv6 import wkv6_chunked


def wkv6(r, k, v, lw, u, *, chunk: int = 128, force_ref: bool = False,
         interpret: bool = False):
    """r,k,v,lw: (b, s, H, K); u: (H, K) -> y (b, s, H, K) f32."""
    on_tpu = jax.default_backend() == "tpu"
    if not ((on_tpu or interpret) and not force_ref):
        return ref.ref_wkv6(r, k, v, lw, u)
    b, s, H, K = r.shape
    Q = min(chunk, s)
    while s % Q != 0:
        Q -= 1
    nc = s // Q

    def split(x):
        return x.reshape(b, nc, Q, H, K)

    rs, ks, vs, lws = map(split, (r, k, v, lw))
    cum = jnp.cumsum(lws, axis=2)
    y = wkv6_chunked(rs, ks, vs, cum, lws, u, interpret=interpret)
    return y.reshape(b, s, H, K)
