"""Pure-jnp oracle for WKV6: the sequential recurrence (exact)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_wkv6(r, k, v, lw, u):
    """Sequential WKV6. r,k,v,lw: (b, s, H, K) f32; u: (H, K).
    Returns y (b, s, H, K):
        S_t = diag(w_t) S_{t-1} + k_t v_t^T;  w = exp(lw)
        y_t = r_t^T S_{t-1} + (r_t . u . k_t) v_t
    """
    b, s, H, K = r.shape
    w = jnp.exp(lw)

    def step(S, xs):
        rt, kt, vt, wt = xs                      # (b, H, K)
        y = (jnp.einsum("bhk,bhkv->bhv", rt, S)
             + jnp.einsum("bhk,hk,bhk->bh", rt, u, kt)[..., None] * vt)
        S_new = wt[..., None] * S + kt[..., None] * vt[:, :, None, :]
        return S_new, y

    S0 = jnp.zeros((b, H, K, K), jnp.float32)
    _, ys = jax.lax.scan(step, S0,
                         (r.swapaxes(0, 1), k.swapaxes(0, 1),
                          v.swapaxes(0, 1), w.swapaxes(0, 1)))
    return ys.swapaxes(0, 1)
