"""jit'd dispatch wrappers for the fused scoring kernels.

On TPU runs the Pallas kernels; elsewhere (or when ``force_ref``) falls
back to the pure-jnp oracles (numerically identical, used by tests). The
proxy params come straight from repro.core.encoder's param tree.

``score_collection`` is the single-query entry; ``score_collection_multi``
scores a (Q, D) stack of query embeddings against one proxy in a single
pass per chunk (the multi-query kernel variant). The engine's streaming
hot path lives in repro.engine.executor and calls ``score_tile_multi``
per prefetched tile.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.encoder import encoder_apply, l2_normalize
from repro.kernels.fused_scoring import ref
from repro.kernels.fused_scoring.scoring import fused_scores, \
    fused_scores_multi


def _unpack(params):
    ls = params["layers"]
    assert len(ls) == 3, "fused kernel is specialized for 3-layer proxies"
    return (ls["l0"]["w"], ls["l0"]["b"], ls["l1"]["w"], ls["l1"]["b"],
            ls["l2"]["w"], ls["l2"]["b"])


def score_collection(params, e_q, embeds, *, chunk: int = 65536,
                     force_ref: bool = False,
                     interpret: bool = False) -> np.ndarray:
    """(N, D) document embeddings -> (N,) scores via the fused kernel."""
    w1, b1, w2, b2, w3, b3 = _unpack(params)
    zq = l2_normalize(encoder_apply(params, e_q))
    on_tpu = jax.default_backend() == "tpu"
    use_kernel = on_tpu or interpret
    outs = []
    n = embeds.shape[0]
    for start in range(0, n, chunk):
        tile = jnp.asarray(embeds[start:start + chunk])
        if use_kernel and not force_ref:
            outs.append(np.asarray(fused_scores(
                tile, w1, b1, w2, b2, w3, b3, zq, interpret=interpret)))
        else:
            outs.append(np.asarray(ref.ref_scores(
                tile, w1, b1, w2, b2, w3, b3, zq)))
    return np.concatenate(outs).astype(np.float32)


def normalized_query_latents(params, e_qs) -> jnp.ndarray:
    """(Q, D) query embeddings -> (Q, L) unit latents for the multi
    kernel; ``params=None`` means raw-embedding cosine (no proxy)."""
    e_qs = jnp.atleast_2d(jnp.asarray(e_qs))
    if params is None:
        return l2_normalize(e_qs)
    return l2_normalize(encoder_apply(params, e_qs))


def score_tile_multi(params, zq_stack, tile, *, force_ref: bool = False,
                     interpret: bool = False) -> jnp.ndarray:
    """One document tile (B, D) x (Q, L) normalized latents -> (B, Q).

    Dispatches to the fused multi-query Pallas kernel on TPU (or under
    ``interpret``); otherwise the jnp oracle. ``params=None`` (raw
    cosine) has no MLP to fuse and is a plain stacked matmul.
    """
    tile = jnp.asarray(tile)
    if params is None:
        return 0.5 * (1.0 + l2_normalize(tile) @ zq_stack.T)
    w1, b1, w2, b2, w3, b3 = _unpack(params)
    on_tpu = jax.default_backend() == "tpu"
    if (on_tpu or interpret) and not force_ref:
        return fused_scores_multi(tile, w1, b1, w2, b2, w3, b3, zq_stack,
                                  interpret=interpret)
    return ref.ref_scores_multi(tile, w1, b1, w2, b2, w3, b3, zq_stack)


def score_collection_multi(params, e_qs, embeds, *, chunk: int = 65536,
                           force_ref: bool = False,
                           interpret: bool = False) -> np.ndarray:
    """(N, D) documents x (Q, D) query embeddings sharing one proxy ->
    (N, Q) scores via the fused multi-query kernel."""
    zq = normalized_query_latents(params, e_qs)
    outs = []
    n = embeds.shape[0]
    for start in range(0, n, chunk):
        outs.append(np.asarray(score_tile_multi(
            params, zq, embeds[start:start + chunk], force_ref=force_ref,
            interpret=interpret)))
    return np.concatenate(outs).astype(np.float32)
