"""jit'd dispatch wrapper for the fused scoring kernel.

On TPU runs the Pallas kernel; elsewhere (or when ``force_ref``) falls
back to the pure-jnp oracle (numerically identical, used by tests). The
proxy params come straight from repro.core.encoder's param tree.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.encoder import encoder_apply, l2_normalize
from repro.kernels.fused_scoring import ref
from repro.kernels.fused_scoring.scoring import fused_scores


def _unpack(params):
    ls = params["layers"]
    assert len(ls) == 3, "fused kernel is specialized for 3-layer proxies"
    return (ls["l0"]["w"], ls["l0"]["b"], ls["l1"]["w"], ls["l1"]["b"],
            ls["l2"]["w"], ls["l2"]["b"])


def score_collection(params, e_q, embeds, *, chunk: int = 65536,
                     force_ref: bool = False,
                     interpret: bool = False) -> np.ndarray:
    """(N, D) document embeddings -> (N,) scores via the fused kernel."""
    w1, b1, w2, b2, w3, b3 = _unpack(params)
    zq = l2_normalize(encoder_apply(params, e_q))
    on_tpu = jax.default_backend() == "tpu"
    use_kernel = on_tpu or interpret
    outs = []
    n = embeds.shape[0]
    for start in range(0, n, chunk):
        tile = jnp.asarray(embeds[start:start + chunk])
        if use_kernel and not force_ref:
            outs.append(np.asarray(fused_scores(
                tile, w1, b1, w2, b2, w3, b3, zq, interpret=interpret)))
        else:
            outs.append(np.asarray(ref.ref_scores(
                tile, w1, b1, w2, b2, w3, b3, zq)))
    return np.concatenate(outs).astype(np.float32)
