"""Pure-jnp oracle for the fused scoring kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ref_scores(docs, w1, b1, w2, b2, w3, b3, zq_normalized):
    h = jax.nn.gelu(docs.astype(jnp.float32) @ w1.astype(jnp.float32) + b1)
    h = jax.nn.gelu(h @ w2.astype(jnp.float32) + b2)
    z = h @ w3.astype(jnp.float32) + b3
    z = z / jnp.maximum(jnp.linalg.norm(z, axis=-1, keepdims=True), 1e-8)
    return 0.5 * (1.0 + z @ zq_normalized)


def ref_scores_multi(docs, w1, b1, w2, b2, w3, b3, zq_stack):
    """Multi-query oracle: zq_stack (Q, L) unit rows -> (N, Q) scores."""
    h = jax.nn.gelu(docs.astype(jnp.float32) @ w1.astype(jnp.float32) + b1)
    h = jax.nn.gelu(h @ w2.astype(jnp.float32) + b2)
    z = h @ w3.astype(jnp.float32) + b3
    z = z / jnp.maximum(jnp.linalg.norm(z, axis=-1, keepdims=True), 1e-8)
    return 0.5 * (1.0 + z @ zq_stack.T)
