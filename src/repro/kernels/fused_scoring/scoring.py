"""Pallas TPU kernels: fused proxy scoring over a document tile.

The ScaleDoc online hot loop — for every query, every document embedding
runs through the 3-layer proxy MLP, is L2-normalized, and dotted with the
normalized query latent. Done naively, each stage round-trips hidden
activations through HBM; these kernels keep the whole per-tile pipeline
in VMEM:

    tile (Bn, D) -> h1 = gelu(tile @ W1 + b1)      (Bn, H)
                 -> h2 = gelu(h1 @ W2 + b2)        (Bn, H)
                 -> z  = h2 @ W3 + b3              (Bn, L)
                 -> s  = 0.5 * (1 + (z/|z|) . zq)  (Bn,)

Two variants share that pipeline:

  * ``fused_scores``       — one query latent zq (L,), scores (N,);
  * ``fused_scores_multi`` — a (Q, L) *stack* of query latents, scores
    (N, Q). The MLP (the dominant cost) runs once per tile and the final
    dot generalizes to one (Bn, L) x (L, Q) matmul, so Q predicates cost
    one encoder pass instead of Q — the engine's batched multi-predicate
    path stays inside the kernel instead of bolting a stacked z_q matmul
    on after it.

Grid: one program per document tile (N / BLOCK_N). Weights are small
(D*H + H*H + H*L floats) and are mapped whole into VMEM per program; the
MXU sees three back-to-back matmuls with 128-aligned contraction dims.

VMEM budget @ defaults (D=4096, H=512, L=128, BLOCK_N=128, f32):
  W1 8 MiB + W2 1 MiB + W3 0.25 MiB + tile 2 MiB + activations < 0.5 MiB
  ~= 12 MiB < 16 MiB v5e VMEM.
The multi-query variant adds zq (Qp, L) + out (Bn, Qp) — at Qp=64 that
is < 64 KiB, so the budget is unchanged.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_N = 128


def _scoring_kernel(docs_ref, w1_ref, b1_ref, w2_ref, b2_ref, w3_ref,
                    b3_ref, zq_ref, out_ref):
    docs = docs_ref[...].astype(jnp.float32)           # (Bn, D)
    h = jnp.dot(docs, w1_ref[...].astype(jnp.float32),
                preferred_element_type=jnp.float32) + b1_ref[...]
    h = jax.nn.gelu(h)
    h = jnp.dot(h, w2_ref[...].astype(jnp.float32),
                preferred_element_type=jnp.float32) + b2_ref[...]
    h = jax.nn.gelu(h)
    z = jnp.dot(h, w3_ref[...].astype(jnp.float32),
                preferred_element_type=jnp.float32) + b3_ref[...]
    norm = jnp.sqrt(jnp.maximum(jnp.sum(z * z, axis=-1, keepdims=True),
                                1e-16))
    zq = zq_ref[...]                                    # (L,) normalized
    cos = jnp.sum((z / norm) * zq[None, :], axis=-1)
    out_ref[...] = 0.5 * (1.0 + cos)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def fused_scores(docs: jnp.ndarray, w1, b1, w2, b2, w3, b3,
                 zq_normalized: jnp.ndarray, *, block_n: int = BLOCK_N,
                 interpret: bool = False) -> jnp.ndarray:
    """docs: (N, D) -> scores (N,) in [0, 1]. zq_normalized: (L,) unit."""
    n, d = docs.shape
    h = w1.shape[1]
    l = w3.shape[1]
    pad = (-n) % block_n
    if pad:
        docs = jnp.pad(docs, ((0, pad), (0, 0)))
    grid = ((n + pad) // block_n,)

    out = pl.pallas_call(
        _scoring_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((d, h), lambda i: (0, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((h, h), lambda i: (0, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((h, l), lambda i: (0, 0)),
            pl.BlockSpec((l,), lambda i: (0,)),
            pl.BlockSpec((l,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_n,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n + pad,), jnp.float32),
        interpret=interpret,
    )(docs, w1, b1, w2, b2, w3, b3, zq_normalized)
    return out[:n]


def _scoring_kernel_multi(docs_ref, w1_ref, b1_ref, w2_ref, b2_ref, w3_ref,
                          b3_ref, zq_ref, out_ref):
    docs = docs_ref[...].astype(jnp.float32)           # (Bn, D)
    h = jnp.dot(docs, w1_ref[...].astype(jnp.float32),
                preferred_element_type=jnp.float32) + b1_ref[...]
    h = jax.nn.gelu(h)
    h = jnp.dot(h, w2_ref[...].astype(jnp.float32),
                preferred_element_type=jnp.float32) + b2_ref[...]
    h = jax.nn.gelu(h)
    z = jnp.dot(h, w3_ref[...].astype(jnp.float32),
                preferred_element_type=jnp.float32) + b3_ref[...]
    norm = jnp.sqrt(jnp.maximum(jnp.sum(z * z, axis=-1, keepdims=True),
                                1e-16))
    zq = zq_ref[...]                                    # (Qp, L) normalized
    cos = jnp.dot(z / norm, zq.T,
                  preferred_element_type=jnp.float32)   # (Bn, Qp)
    out_ref[...] = 0.5 * (1.0 + cos)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def fused_scores_multi(docs: jnp.ndarray, w1, b1, w2, b2, w3, b3,
                       zq_stack: jnp.ndarray, *, block_n: int = BLOCK_N,
                       interpret: bool = False) -> jnp.ndarray:
    """docs: (N, D), zq_stack: (Q, L) unit rows -> scores (N, Q) in [0,1].

    One MLP pass per document tile regardless of Q; the query dim is
    padded to a multiple of 8 (f32 sublane) so the final matmul tiles
    cleanly, and the pad columns are sliced off before returning.
    """
    n, d = docs.shape
    h = w1.shape[1]
    l = w3.shape[1]
    q = zq_stack.shape[0]
    pad = (-n) % block_n
    if pad:
        docs = jnp.pad(docs, ((0, pad), (0, 0)))
    qpad = (-q) % 8
    if qpad:
        zq_stack = jnp.pad(zq_stack, ((0, qpad), (0, 0)))
    qp = q + qpad
    grid = ((n + pad) // block_n,)

    out = pl.pallas_call(
        _scoring_kernel_multi,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
            pl.BlockSpec((d, h), lambda i: (0, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((h, h), lambda i: (0, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((h, l), lambda i: (0, 0)),
            pl.BlockSpec((l,), lambda i: (0,)),
            pl.BlockSpec((qp, l), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, qp), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n + pad, qp), jnp.float32),
        interpret=interpret,
    )(docs, w1, b1, w2, b2, w3, b3, zq_stack)
    return out[:n, :q]
