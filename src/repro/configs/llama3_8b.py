"""llama3-8b [dense] — GQA, 128k vocab. [arXiv:2407.21783]
32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=128256
"""
from repro.config.base import BLOCK_ATTN, ModelConfig
from repro.config.registry import register

FULL = ModelConfig(
    name="llama3-8b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=128256, rope_theta=500000.0,
    tie_embeddings=False,
    block_pattern=(BLOCK_ATTN,),
)

SMOKE = ModelConfig(
    name="llama3-8b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=1,
    d_ff=224, vocab_size=256, tie_embeddings=False,
    block_pattern=(BLOCK_ATTN,), dtype="float32", remat="none",
)

register(FULL, SMOKE)
