"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared-weight attention blocks.
54L d_model=2560 32H (GQA kv=32) d_ff=10240 ssm_state=64 vocab=32000
[arXiv:2411.15242]

Pattern: every 6th layer applies the single shared attention+MLP block
(Zamba2's shared transformer block; per-application LoRA omitted — noted
in DESIGN.md).
"""
from repro.config.base import (BLOCK_MAMBA2, BLOCK_SHARED_ATTN, ModelConfig,
                               SSMConfig)
from repro.config.registry import register

FULL = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32,
    d_ff=10240, vocab_size=32000,
    ssm=SSMConfig(state_dim=64, conv_width=4, head_dim=64, expand=2),
    block_pattern=(BLOCK_MAMBA2,) * 5 + (BLOCK_SHARED_ATTN,),
)

SMOKE = ModelConfig(
    name="zamba2-2.7b-smoke", family="hybrid",
    num_layers=6, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=128, vocab_size=256,
    ssm=SSMConfig(state_dim=16, conv_width=4, head_dim=16, expand=2,
                  chunk=8),
    block_pattern=(BLOCK_MAMBA2,) * 5 + (BLOCK_SHARED_ATTN,),
    dtype="float32", remat="none",
)

register(FULL, SMOKE)
