"""Cell enumeration: (architecture x input shape) with skip rationale.

All 40 assigned cells are enumerated; `cell_is_runnable` marks the cells
excluded per the assignment rules (long_500k for pure full-attention archs,
enc-dec 500k decode), with human-readable reasons recorded for
EXPERIMENTS.md §Dry-run.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from repro.config.base import ALL_SHAPES, InputShape, ModelConfig
from repro.config.registry import get_arch, list_archs

# archs allowed to run long_500k (sub-quadratic context handling)
LONG_OK = {
    "rwkv6-7b": "attention-free: O(1) decode state",
    "zamba2-2.7b": "hybrid: SSM state carries context; only 9 shared-attn "
                   "applications keep KV",
    "gemma3-12b": "5:1 local:global — only 8/48 layers keep full 500k KV "
                  "(window=1024 elsewhere)",
}

LONG_SKIP = {
    "smollm-360m": "pure full attention: 500k KV/layer unsupported by "
                   "assignment rules",
    "llama3-8b": "pure full attention",
    "codeqwen1.5-7b": "pure full attention (kv=32: 500k KV is 2x llama3 "
                      "per layer)",
    "dbrx-132b": "pure full attention MoE",
    "qwen3-moe-30b-a3b": "pure full attention MoE",
    "internvl2-1b": "pure full attention VLM backbone",
    "whisper-base": "enc-dec with 448-token decoder regime; full attention",
}


def skip_reason(arch: str, shape: InputShape) -> Optional[str]:
    if shape.name == "long_500k" and arch not in LONG_OK:
        return LONG_SKIP.get(arch, "pure full attention")
    return None


def cell_is_runnable(arch: str, shape: InputShape) -> bool:
    return skip_reason(arch, shape) is None


def arch_cells(arch: Optional[str] = None
               ) -> List[Tuple[str, InputShape, Optional[str]]]:
    """All 40 (arch, shape, skip_reason) cells (or one arch's 4)."""
    archs = [arch] if arch else list_archs()
    out = []
    for a in archs:
        for s in ALL_SHAPES:
            out.append((a, s, skip_reason(a, s)))
    return out
