"""internvl2-1b [vlm] — InternViT frontend STUBBED (precomputed patch
embeddings); backbone = InternLM2-like dense LM.
24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655
[arXiv:2404.16821]
"""
from repro.config.base import BLOCK_ATTN, ModelConfig
from repro.config.registry import register

FULL = ModelConfig(
    name="internvl2-1b", family="vlm",
    num_layers=24, d_model=896, num_heads=14, num_kv_heads=2,
    d_ff=4864, vocab_size=151655,
    frontend="vision",
    block_pattern=(BLOCK_ATTN,),
)

SMOKE = ModelConfig(
    name="internvl2-1b-smoke", family="vlm",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=160, vocab_size=256,
    frontend="vision",
    block_pattern=(BLOCK_ATTN,), dtype="float32", remat="none",
)

register(FULL, SMOKE)
