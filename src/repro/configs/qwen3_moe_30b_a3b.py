"""qwen3-moe-30b-a3b [moe] — 128 experts top-8, fine-grained d_ff=768.
48L d_model=2048 32H (GQA kv=4) vocab=151936
[hf:Qwen/Qwen3-30B-A3B]
"""
from repro.config.base import BLOCK_ATTN, ModelConfig, MoEConfig
from repro.config.registry import register

FULL = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4,
    d_ff=768, vocab_size=151936, rope_theta=1000000.0,
    head_dim=128, tie_embeddings=False,
    moe=MoEConfig(num_experts=128, top_k=8),
    block_pattern=(BLOCK_ATTN,),
)

SMOKE = ModelConfig(
    name="qwen3-moe-30b-a3b-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=32, vocab_size=256, head_dim=16, tie_embeddings=False,
    moe=MoEConfig(num_experts=8, top_k=2),
    block_pattern=(BLOCK_ATTN,), dtype="float32", remat="none",
)

register(FULL, SMOKE)
