"""gemma3-12b [dense] — 5:1 local:global attention, 256k vocab.
48L d_model=3840 16H (GQA kv=8) d_ff=15360 vocab=262144 window=1024
[hf:google/gemma-3-1b-pt]
"""
from repro.config.base import BLOCK_ATTN, BLOCK_LOCAL_ATTN, ModelConfig
from repro.config.registry import register

FULL = ModelConfig(
    name="gemma3-12b", family="dense",
    num_layers=48, d_model=3840, num_heads=16, num_kv_heads=8,
    d_ff=15360, vocab_size=262144, rope_theta=1000000.0,
    sliding_window=1024,
    block_pattern=(BLOCK_LOCAL_ATTN,) * 5 + (BLOCK_ATTN,),
)

SMOKE = ModelConfig(
    name="gemma3-12b-smoke", family="dense",
    num_layers=6, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=160, vocab_size=512, sliding_window=16,
    block_pattern=(BLOCK_LOCAL_ATTN,) * 5 + (BLOCK_ATTN,),
    dtype="float32", remat="none",
)

register(FULL, SMOKE)
