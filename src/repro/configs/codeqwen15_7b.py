"""codeqwen1.5-7b [dense] — qwen1.5 arch (MHA-style KV: kv=32).
32L d_model=4096 32H (GQA kv=32) d_ff=13440 vocab=92416
[hf:Qwen/CodeQwen1.5-7B; hf]
"""
from repro.config.base import BLOCK_ATTN, ModelConfig
from repro.config.registry import register

FULL = ModelConfig(
    name="codeqwen1.5-7b", family="dense",
    num_layers=32, d_model=4096, num_heads=32, num_kv_heads=32,
    d_ff=13440, vocab_size=92416, rope_theta=1000000.0,
    tie_embeddings=False,
    block_pattern=(BLOCK_ATTN,),
)

SMOKE = ModelConfig(
    name="codeqwen1.5-7b-smoke", family="dense",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=192, vocab_size=256, tie_embeddings=False,
    block_pattern=(BLOCK_ATTN,), dtype="float32", remat="none",
)

register(FULL, SMOKE)
