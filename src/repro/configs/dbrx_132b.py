"""dbrx-132b [moe] — 16 experts top-4, fine-grained.
40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352
[hf:databricks/dbrx-base]
"""
from repro.config.base import BLOCK_ATTN, ModelConfig, MoEConfig
from repro.config.registry import register

FULL = ModelConfig(
    name="dbrx-132b", family="moe",
    num_layers=40, d_model=6144, num_heads=48, num_kv_heads=8,
    d_ff=10752, vocab_size=100352, rope_theta=500000.0,
    tie_embeddings=False,
    moe=MoEConfig(num_experts=16, top_k=4),
    block_pattern=(BLOCK_ATTN,),
)

SMOKE = ModelConfig(
    name="dbrx-132b-smoke", family="moe",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
    d_ff=96, vocab_size=256, tie_embeddings=False,
    moe=MoEConfig(num_experts=4, top_k=2),
    block_pattern=(BLOCK_ATTN,), dtype="float32", remat="none",
)

register(FULL, SMOKE)
