"""rwkv6-7b [ssm] — Finch, attention-free, data-dependent decay.
32L d_model=4096 d_ff=14336 vocab=65536 head_dim=64
[arXiv:2404.05892]
"""
from repro.config.base import BLOCK_RWKV6, ModelConfig, RWKVConfig
from repro.config.registry import register

FULL = ModelConfig(
    name="rwkv6-7b", family="ssm",
    num_layers=32, d_model=4096, num_heads=64, num_kv_heads=64,
    d_ff=14336, vocab_size=65536,
    rwkv=RWKVConfig(head_dim=64),
    block_pattern=(BLOCK_RWKV6,),
)

SMOKE = ModelConfig(
    name="rwkv6-7b-smoke", family="ssm",
    num_layers=2, d_model=64, num_heads=4, num_kv_heads=4,
    d_ff=224, vocab_size=256,
    rwkv=RWKVConfig(head_dim=16),
    block_pattern=(BLOCK_RWKV6,), dtype="float32", remat="none",
)

register(FULL, SMOKE)
