"""Assigned-architecture configs. Importing this package registers all
architectures with repro.config.registry."""
from repro.configs import (  # noqa: F401
    codeqwen15_7b,
    dbrx_132b,
    gemma3_12b,
    internvl2_1b,
    llama3_8b,
    qwen3_moe_30b_a3b,
    rwkv6_7b,
    smollm_360m,
    whisper_base,
    zamba2_27b,
)
from repro.configs.shapes import arch_cells, cell_is_runnable, skip_reason  # noqa: F401
