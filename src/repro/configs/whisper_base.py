"""whisper-base [audio] — enc-dec; conv frontend STUBBED (precomputed frame
embeddings). 6L enc + 6L dec, d_model=512 8H d_ff=2048 vocab=51865.
[arXiv:2212.04356]
"""
from repro.config.base import BLOCK_ATTN, ModelConfig
from repro.config.registry import register

FULL = ModelConfig(
    name="whisper-base", family="audio",
    num_layers=6, encoder_layers=6, encoder_d_ff=2048,
    d_model=512, num_heads=8, num_kv_heads=8,
    d_ff=2048, vocab_size=51865,
    frontend="audio", act="gelu",
    block_pattern=(BLOCK_ATTN,),
)

SMOKE = ModelConfig(
    name="whisper-base-smoke", family="audio",
    num_layers=2, encoder_layers=2, encoder_d_ff=96,
    d_model=48, num_heads=4, num_kv_heads=4,
    d_ff=96, vocab_size=256,
    frontend="audio", act="gelu",
    block_pattern=(BLOCK_ATTN,), dtype="float32", remat="none",
)

register(FULL, SMOKE)
