from repro.optimizer import adamw  # noqa: F401
from repro.optimizer.adamw import (  # noqa: F401
    AdamWState,
    apply_updates,
    clip_by_global_norm,
    global_norm,
    init,
    schedule,
    update,
)
