"""Hand-rolled AdamW + LR schedules + global-norm clipping.

No optax dependency: state is a plain pytree {step, mu, nu}, update is a
pure function — trivially pjit-able (state shards like params).

``apply_updates`` returns a metrics dict alongside the new state (what
the LM training loop logs); ``update`` is the donation-safe fast path
used inside the scanned proxy trainer's step body.
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.config.base import OptimizerConfig


class AdamWState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def schedule(cfg: OptimizerConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Warmup + {cosine, linear, constant} decay."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                 0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    elif cfg.schedule == "linear":
        decay = 1.0 - t
    else:
        decay = jnp.ones_like(t)
    return cfg.lr * warm * decay


def init(cfg: OptimizerConfig, params: Any) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      mu=jax.tree.map(zeros, params),
                      nu=jax.tree.map(zeros, params))


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(grads: Any, max_norm: float) -> Tuple[Any, jnp.ndarray]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gnorm, 1e-9))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), gnorm


def update(cfg: OptimizerConfig, params: Any, grads: Any,
           state: AdamWState) -> Tuple[Any, AdamWState]:
    """Donation-safe update path: ``apply_updates`` minus the metrics dict.

    Every output leaf has the shape and dtype of the matching input leaf
    (params keep their dtype, mu/nu stay float32, step stays int32), so a
    surrounding ``jax.jit(..., donate_argnums=...)`` can alias the params
    and optimizer-state buffers in place. This is the entry the scanned
    proxy trainer (repro.core.trainer) calls per scan step, where the
    metrics dict of ``apply_updates`` would be dead weight in the carry.
    """
    new_params, new_state, _, _ = _update(cfg, params, grads, state)
    return new_params, new_state


def apply_updates(cfg: OptimizerConfig, params: Any, grads: Any,
                  state: AdamWState) -> Tuple[Any, AdamWState, Dict[str, Any]]:
    new_params, new_state, gnorm, lr = _update(cfg, params, grads, state)
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}


def _update(cfg: OptimizerConfig, params: Any, grads: Any,
            state: AdamWState) -> Tuple[Any, AdamWState, jnp.ndarray,
                                        jnp.ndarray]:
    grads, gnorm = (clip_by_global_norm(grads, cfg.grad_clip)
                    if cfg.grad_clip > 0
                    else (jax.tree.map(lambda g: g.astype(jnp.float32), grads),
                          global_norm(grads)))
    step = state.step + 1
    lr = schedule(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay > 0 and p.ndim >= 2:  # decay matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.mu)
    flat_v = jax.tree.leaves(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), gnorm, lr
