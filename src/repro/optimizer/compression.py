"""int8 gradient compression with error feedback (DP all-reduce shrink).

At pod scale the data-parallel gradient all-reduce is the dominant
inter-pod collective. Quantizing gradients to int8 with per-tensor scales
cuts those bytes 4x (bf16) / 2x (f32); the residual (quantization error)
is fed back into the next step's gradient so the scheme stays unbiased in
the long run (error-feedback SGD, 1-bit Adam lineage).

Usage inside a train step:
    grads_q, new_residual = compress_decompress(grads, residual)
    ... apply optimizer on grads_q ...

Under pjit the quantize/dequantize ops shard like the gradients; XLA
places the all-reduce on the int8 tensors when compression is enabled in
the step function (see runtime/train_loop.py).
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def _quantize(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def init_residual(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compress_decompress(grads: Any, residual: Any) -> Tuple[Any, Any]:
    """Error-feedback int8 round trip. Returns (grads_hat, new_residual)."""
    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        q, scale = _quantize(corrected)
        ghat = _dequantize(q, scale)
        return ghat, corrected - ghat

    flat_g, tree = jax.tree.flatten(grads)
    flat_r = jax.tree.leaves(residual)
    outs = [one(g, r) for g, r in zip(flat_g, flat_r)]
    return (tree.unflatten([o[0] for o in outs]),
            tree.unflatten([o[1] for o in outs]))


def compression_ratio(params: Any, from_dtype=jnp.float32) -> float:
    """Bytes saved on the wire for one gradient all-reduce."""
    return jnp.dtype(from_dtype).itemsize / jnp.dtype(jnp.int8).itemsize
